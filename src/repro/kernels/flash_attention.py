"""Pallas TPU flash attention (forward): causal + sliding-window, fp32
accumulation, online softmax.

TPU adaptation (vs. the CUDA flash-attention algorithm): the kernel tiles
HBM→VMEM with BlockSpecs sized for the MXU — q blocks (Bq × hd) and kv
blocks (Bk × hd) with Bq, Bk multiples of the 128-lane register tile and
hd padded to 128. Softmax state (m, l) and the output accumulator live in
VMEM scratch carried across the kv-block loop (the innermost *sequential*
grid dim) — the TPU grid plays the role CUDA thread-block persistence
plays on GPU.

Grid: (batch·heads, q_blocks, kv_blocks), kv innermost.
Causality & sliding window are enforced per-element inside the block and
whole irrelevant blocks are skipped with ``pl.when`` (block-level
early-out — on TPU this saves the MXU issue, not the DMA, so the wrapper
also clips the kv grid to the causal frontier via index_map clamping).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import CompilerParams

NEG_INF = -1e30
_LANES = 128


def _attn_kernel(
    q_ref, k_ref, v_ref, o_ref,  # VMEM tiles
    m_scr, l_scr, acc_scr,       # VMEM scratch carried over kv blocks
    *, scale: float, causal: bool, window: int, bq: int, bk: int, sk: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * bq
    k_start = ki * bk

    run = jnp.bool_(True)
    if causal:
        run = run & (k_start <= q_start + bq - 1)
    if window > 0:
        run = run & (k_start + bk - 1 >= q_start - window + 1)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32) * scale  # (bq, hd)
        k = k_ref[0].astype(jnp.float32)          # (bk, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bq, bk)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = k_pos < sk
        if causal:
            mask = mask & (k_pos <= q_pos)
        if window > 0:
            mask = mask & (k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...][:, :1]                       # (bq, 1)
        m_cur = s.max(axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)                    # (bq, 1)
        l_prev = l_scr[...][:, :1]
        l_scr[...] = jnp.broadcast_to(l_prev * corr + p.sum(-1, keepdims=True), l_scr.shape)
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        v = v_ref[0].astype(jnp.float32)                  # (bk, hd)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32
        )

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...][:, :1], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jnp.ndarray,  # (B, Sq, H, hd)
    k: jnp.ndarray,  # (B, Sk, H, hd)  — kv heads already repeated to H
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    scale: Optional[float] = None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    scale = float(scale if scale is not None else hd ** -0.5)
    bq = min(block_q, max(sq, 8))
    bk = min(block_k, max(sk, 8))

    # (B, S, H, hd) → (B·H, S, hd)
    qt = q.transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
    kt = k.transpose(0, 2, 1, 3).reshape(b * h, sk, hd)
    vt = v.transpose(0, 2, 1, 3).reshape(b * h, sk, hd)

    pad_q = (-sq) % bq
    pad_k = (-sk) % bk
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kt = jnp.pad(kt, ((0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, pad_k), (0, 0)))
    nq = qt.shape[1] // bq
    nk = kt.shape[1] // bk

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window, bq=bq, bk=bk, sk=sk
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, hd), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, bk, hd), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, qt.shape[1], hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, _LANES), jnp.float32),  # m
            pltpu.VMEM((bq, _LANES), jnp.float32),  # l
            pltpu.VMEM((bq, hd), jnp.float32),      # acc
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qt, kt, vt)
    if pad_q:
        out = out[:, :sq]
    return out.reshape(b, h, sq, hd).transpose(0, 2, 1, 3)
