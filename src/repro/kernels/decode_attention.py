"""Pallas TPU decode attention: one query token per sequence against a
(possibly ring-buffered) KV cache.

Decode is bandwidth-bound — the whole cache is streamed once. The kernel
keeps the q row resident in VMEM and tiles the cache along S with online
softmax (m, l, acc) in scratch, exactly the flash recurrence with Sq = 1.
GQA is exploited natively: the *kv-head* is the grid axis and all
``group`` q heads sharing it are processed against one cache tile —
cutting cache reads by the group factor vs. head-major layouts.

Grid: (batch, kv_heads, s_blocks) — s innermost/sequential.
q: (B, G, KV, hd) grouped layout; k/v cache: (B, S, KV, hd).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import CompilerParams, MemorySpace

NEG_INF = -1e30
_LANES = 128


def _decode_kernel(
    len_ref, q_ref, k_ref, v_ref, o_ref,
    m_scr, l_scr, acc_scr,
    *, scale: float, window: int, bs: int, groups: int,
):
    si = pl.program_id(2)
    ns = pl.num_programs(2)
    cache_len = len_ref[0]

    @pl.when(si == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    s_start = si * bs
    run = s_start < cache_len
    if window > 0:
        run = run & (s_start + bs - 1 >= cache_len - window)

    @pl.when(run)
    def _body():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale  # (G, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)          # (bs, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (G, bs)
        pos = s_start + jax.lax.broadcasted_iota(jnp.int32, (groups, bs), 1)
        mask = pos < cache_len
        if window > 0:
            mask = mask & (pos >= cache_len - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...][:, :1]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_prev = l_scr[...][:, :1]
        l_scr[...] = jnp.broadcast_to(l_prev * corr + p.sum(-1, keepdims=True), l_scr.shape)
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32
        )

    @pl.when(si == ns - 1)
    def _finish():
        l = jnp.maximum(l_scr[...][:, :1], 1e-30)
        o_ref[0, :, 0, :] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "scale", "block_s", "interpret")
)
def decode_attention(
    q: jnp.ndarray,        # (B, 1, H, hd)
    k_cache: jnp.ndarray,  # (B, S, KV, hd)
    v_cache: jnp.ndarray,  # (B, S, KV, hd)
    cache_len: jnp.ndarray,  # () int32 — valid entries
    *,
    window: int = 0,
    scale: Optional[float] = None,
    block_s: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    b, _, h, hd = q.shape
    s_max, kv = k_cache.shape[1], k_cache.shape[2]
    groups = h // kv
    scale = float(scale if scale is not None else hd ** -0.5)
    bs = min(block_s, s_max)
    pad = (-s_max) % bs
    if pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
    ns = k_cache.shape[1] // bs
    # grouped q layout: (B, G, KV, hd)
    qg = q[:, 0].reshape(b, kv, groups, hd).transpose(0, 2, 1, 3)
    clen = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32).reshape(-1)[:1], (1,))

    kernel = functools.partial(
        _decode_kernel, scale=scale, window=window, bs=bs, groups=groups
    )
    out = pl.pallas_call(
        kernel,
        grid=(b, kv, ns),
        in_specs=[
            pl.BlockSpec(memory_space=MemorySpace.SMEM),
            pl.BlockSpec((1, groups, 1, hd), lambda bi, ki, si: (bi, 0, ki, 0)),
            pl.BlockSpec((1, bs, 1, hd), lambda bi, ki, si: (bi, si, ki, 0)),
            pl.BlockSpec((1, bs, 1, hd), lambda bi, ki, si: (bi, si, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, groups, 1, hd), lambda bi, ki, si: (bi, 0, ki, 0)),
        out_shape=jax.ShapeDtypeStruct((b, groups, kv, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((groups, _LANES), jnp.float32),
            pltpu.VMEM((groups, _LANES), jnp.float32),
            pltpu.VMEM((groups, hd), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(clen, qg, k_cache, v_cache)
    # (B, G, KV, hd) → (B, 1, H, hd)
    return out.transpose(0, 2, 1, 3).reshape(b, 1, h, hd)
