"""Pallas TPU kernels for the compute hot spots, each with a pure-jnp
oracle in ref.py and a backend-dispatching wrapper in ops.py.

  flash_attention   causal/SWA prefill+train attention (online softmax)
  decode_attention  single-token cache attention, kv-head-major GQA
  rmsnorm           fused (residual+)RMSNorm
  ssd               Mamba2 chunked SSD scan with VMEM-resident state

The paper's own contribution is control-plane (dataflow merge/unmerge),
so these kernels serve the *model zoo* data plane, not the paper §4
algorithms — see DESIGN.md §3.
"""
from .ops import (
    backend,
    decode_attention,
    flash_attention,
    rmsnorm,
    rmsnorm_residual,
    set_backend,
    ssd_scan,
)

__all__ = [
    "backend",
    "decode_attention",
    "flash_attention",
    "rmsnorm",
    "rmsnorm_residual",
    "set_backend",
    "ssd_scan",
]
