"""Trip-count-aware HLO cost extraction.

``compiled.cost_analysis()`` counts a ``while`` body **once**, so any
``lax.scan`` model (layers, grad-accum) is undercounted by the trip count.
This parser walks the partitioned HLO text from ENTRY, multiplying every
computation's costs by the product of enclosing ``known_trip_count``s, and
derives:

  * ``flops``            — 2·M·N·K for every dot (+ conv), loop-corrected
  * ``hbm_bytes``        — Σ (operand + output bytes) of every *top-level*
                            executed instruction (fusion internals excluded:
                            a fusion's HBM traffic is its boundary)
  * ``collective``       — per type: op count, operand bytes, and *wire*
                            bytes per device using ring factors
                            (all-reduce 2(g−1)/g, all-gather/reduce-scatter
                            (g−1)/g, all-to-all (g−1)/g, permute 1×)

All numbers are per-device (the module is the post-SPMD partition).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_NAME_RE = re.compile(r"%([\w.\-]+)")
# "%name = TYPE opcode(" where TYPE may be a (possibly nested) tuple
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*((?:\(.*?\))|(?:\S+))\s+([\w\-]+)\("
)
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")


def _shape_list(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dims = [int(d) for d in m.group(2).split(",") if d]
        out.append((m.group(1), dims))
    return out


def _nbytes(type_str: str) -> int:
    total = 0
    for dt, dims in _shape_list(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


@dataclass
class Instr:
    name: str
    out_type: str
    opcode: str
    operands: List[str]
    attrs: str

    @property
    def out_bytes(self) -> int:
        return _nbytes(self.out_type)


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)  # %name -> type str


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if not s:
            continue
        if cur is None:
            m = _COMP_HDR_RE.match(s)
            if m and s.endswith("{") and "->" in s:
                cur = Computation(m.group(1))
                if s.startswith("ENTRY"):
                    entry = m.group(1)
            continue
        if s == "}":
            comps[cur.name] = cur
            cur = None
            continue
        im = _INSTR_RE.match(s)
        if not im:
            continue
        name, out_type, opcode = im.group(1), im.group(2).strip(), im.group(3)
        # operand names: inside the first paren group
        rest = s[im.end():]
        depth = 1
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    args, attrs = rest[:i], rest[i + 1 :]
                    break
        else:
            args, attrs = rest, ""
        operands = _NAME_RE.findall(args)
        inst = Instr(name, out_type, opcode, operands, attrs)
        cur.instrs.append(inst)
        cur.shapes[name] = out_type
    return comps, entry


def _group_size(attrs: str, default: int = 1) -> int:
    # iota form: replica_groups=[G,S]<=[N] → group size S
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[\d+\]", attrs)
    if m:
        return int(m.group(2))
    # explicit form: replica_groups={{0,1,2,3},{...}}
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", attrs)
    if m:
        return len(m.group(1).split(","))
    return default


def _trip_count(attrs: str) -> int:
    m = re.search(r'"known_trip_count":\s*\{"n":"(\d+)"', attrs)
    return int(m.group(1)) if m else 1


_WIRE_FACTOR = {
    "all-reduce": lambda g: 2.0 * (g - 1) / g,
    "all-gather": lambda g: (g - 1) / g,
    "reduce-scatter": lambda g: (g - 1) / g,
    "all-to-all": lambda g: (g - 1) / g,
    "collective-permute": lambda g: 1.0,
}

_COLLECTIVES = tuple(_WIRE_FACTOR)


def _dot_flops(inst: Instr, comp: Computation) -> float:
    out_shapes = _shape_list(inst.out_type)
    if not out_shapes:
        return 0.0
    out_elems = 1
    for d in out_shapes[0][1]:
        out_elems *= d
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", inst.attrs)
    lhs_type = comp.shapes.get(inst.operands[0]) if inst.operands else None
    k = 1
    if m and lhs_type:
        lhs_shapes = _shape_list(lhs_type)
        if lhs_shapes:
            dims = lhs_shapes[0][1]
            for ci in m.group(1).split(","):
                if ci:
                    idx = int(ci)
                    if idx < len(dims):
                        k *= dims[idx]
    return 2.0 * out_elems * k


def analyze(text: str, top_k: int = 0) -> Dict[str, Any]:
    comps, entry = parse_hlo(text)
    flops = 0.0
    hbm = 0.0
    coll_bytes: Dict[str, float] = defaultdict(float)
    coll_wire: Dict[str, float] = defaultdict(float)
    coll_count: Dict[str, float] = defaultdict(float)
    hbm_by_site: Dict[str, float] = defaultdict(float)  # op_name metadata site
    hbm_by_scope: Dict[str, float] = defaultdict(float)  # named_scope markers
    seen_stack: List[str] = []

    def _site(inst: Instr) -> str:
        m = re.search(r'op_name="([^"]*)"', inst.attrs)
        site = m.group(1) if m else inst.opcode
        return f"{inst.opcode} @ {site[:110]}"

    def _scope(inst: Instr) -> Optional[str]:
        m = re.search(r'op_name="[^"]*?(kernel_\w+)', inst.attrs)
        return m.group(1) if m else None

    def visit(comp_name: str, mult: float, top_level: bool) -> None:
        nonlocal flops, hbm
        comp = comps.get(comp_name)
        if comp is None or comp_name in seen_stack:
            return
        seen_stack.append(comp_name)
        for inst in comp.instrs:
            op = inst.opcode
            base = op
            for sfx in ("-start", "-done", "-update"):
                if base.endswith(sfx):
                    base = base[: -len(sfx)]
            if op == "while":
                tc = _trip_count(inst.attrs)
                m = re.search(r"body=%?([\w.\-]+)", inst.attrs)
                if m:
                    visit(m.group(1), mult * tc, True)
                cm = re.search(r"condition=%?([\w.\-]+)", inst.attrs)
                if cm:
                    visit(cm.group(1), mult * tc, True)
                continue
            if op in ("fusion", "call", "custom-call", "async-start"):
                m = re.search(r"calls=%?([\w.\-]+)", inst.attrs)
                if m:
                    # fusion internals contribute flops but not HBM traffic
                    visit(m.group(1), mult, False)
                if top_level and op != "call":
                    b = mult * _instr_hbm(inst, comp)
                    hbm += b
                    if top_k:
                        hbm_by_site[_site(inst)] += b
                    sc = _scope(inst)
                    if sc:
                        hbm_by_scope[sc] += b
                continue
            if op == "conditional":
                for m in re.finditer(r"(?:branch_computations=\{|true_computation=|false_computation=)%?([\w.\-]+)", inst.attrs):
                    visit(m.group(1), mult, True)
                continue
            if op in ("dot", "dot-general"):
                flops += mult * _dot_flops(inst, comp)
            elif op == "convolution":
                flops += mult * 2.0 * _nbytes(inst.out_type)  # coarse
            if base in _COLLECTIVES and not op.endswith("-done"):
                opb = 0
                for o in inst.operands:
                    t = comp.shapes.get(o)
                    if t:
                        opb += _nbytes(t)
                if opb == 0:
                    opb = inst.out_bytes
                g = _group_size(inst.attrs)
                coll_bytes[base] += mult * opb
                coll_wire[base] += mult * opb * _WIRE_FACTOR[base](max(g, 1))
                coll_count[base] += mult
            if top_level and op not in (
                "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
            ):
                b = mult * _instr_hbm(inst, comp)
                hbm += b
                if top_k:
                    hbm_by_site[_site(inst)] += b
                sc = _scope(inst)
                if sc:
                    hbm_by_scope[sc] += b
        seen_stack.pop()

    def _instr_hbm(inst: Instr, comp: Computation) -> float:
        op = inst.opcode
        if op == "dynamic-slice":
            # reads only the slice (+ scalar indices), writes the slice
            return float(2 * inst.out_bytes)
        if op == "dynamic-update-slice":
            # in-place on unique buffers: read+write the update region only
            upd = comp.shapes.get(inst.operands[1]) if len(inst.operands) > 1 else None
            return float(2 * (_nbytes(upd) if upd else inst.out_bytes))
        if op == "gather":
            # reads only the gathered elements (+ indices)
            return float(2 * inst.out_bytes)
        if op == "scatter":
            # in-place: read+write the update region (operand 2) only
            upd = comp.shapes.get(inst.operands[2]) if len(inst.operands) > 2 else None
            return float(2 * (_nbytes(upd) if upd else inst.out_bytes))
        if op == "fusion":
            return _fusion_hbm(inst, comp)
        b = inst.out_bytes
        for o in inst.operands:
            t = comp.shapes.get(o)
            if t:
                b += _nbytes(t)
        return float(b)

    def _fusion_hbm(inst: Instr, comp: Computation) -> float:
        """Fusion traffic = outputs + operands, with two in-place patterns
        recognized: (a) an operand whose only in-fusion use is a
        dynamic-slice is charged at the slice size (scan-body weight/cache
        slicing); (b) a fusion whose ROOT is a dynamic-update-slice writes
        only the update region (XLA updates unique buffers in place), and
        the buffer operand it updates is likewise not re-read in full."""
        m = re.search(r"calls=%?([\w.\-]+)", inst.attrs)
        fused = comps.get(m.group(1)) if m else None
        b = float(inst.out_bytes)
        dus_buffer_param: Optional[str] = None
        if fused is not None and fused.instrs:
            root = fused.instrs[-1]
            if root.opcode == "dynamic-update-slice" and len(root.operands) > 1:
                upd = fused.shapes.get(root.operands[1])
                if upd is not None:
                    b = float(2 * _nbytes(upd))  # write update; read update src
                    dus_buffer_param = root.operands[0]
        sliced_params: Dict[int, float] = {}
        if fused is not None:
            # map parameter index -> effective read bytes
            param_users: Dict[str, List[Instr]] = defaultdict(list)
            param_idx: Dict[str, int] = {}
            for fi in fused.instrs:
                for o in fi.operands:
                    param_users[o].append(fi)
            order = [fi.name for fi in fused.instrs if fi.opcode == "parameter"]
            for idx, pname in enumerate(order):
                if dus_buffer_param is not None and pname == dus_buffer_param:
                    sliced_params[idx] = 0.0  # in-place updated buffer
                    continue
                users = param_users.get(pname, [])
                # follow through bitcast/copy chains
                expanded: List[Instr] = []
                seen = set()
                stack = list(users)
                while stack:
                    u = stack.pop()
                    if u.name in seen:
                        continue
                    seen.add(u.name)
                    if u.opcode in ("bitcast", "copy", "reshape"):
                        stack.extend(param_users.get(u.name, []))
                    else:
                        expanded.append(u)
                if expanded and all(u.opcode == "dynamic-slice" for u in expanded):
                    sliced_params[idx] = float(
                        sum(u.out_bytes for u in expanded)
                    )
        for i, o in enumerate(inst.operands):
            t = comp.shapes.get(o)
            if not t:
                continue
            if i in sliced_params:
                b += sliced_params[i]
            else:
                b += _nbytes(t)
        return b

    if entry:
        visit(entry, 1.0, True)
    top = sorted(hbm_by_site.items(), key=lambda kv: -kv[1])[:top_k] if top_k else []
    return {
        "flops": flops,
        "hbm_bytes": hbm,
        "hbm_top_sites": [(k, round(v)) for k, v in top],
        "hbm_by_kernel_scope": {k: float(v) for k, v in hbm_by_scope.items()},
        "collective_bytes_by_type": dict(coll_bytes),
        "collective_wire_bytes_by_type": dict(coll_wire),
        "collective_counts_by_type": dict(coll_count),
        "collective_bytes": float(sum(coll_bytes.values())),
        "collective_wire_bytes": float(sum(coll_wire.values())),
        "collective_count": float(sum(coll_count.values())),
    }
