"""Roofline terms from a compiled dry-run artifact (no hardware needed).

  compute term    = HLO_FLOPs / (chips × peak FLOP/s)
  memory term     = HLO_bytes / (chips × HBM bandwidth)
  collective term = collective_bytes / (chips × link bandwidth)

``compiled.cost_analysis()`` runs on the *partitioned* module, so its
flops/bytes are per-device; the collective bytes are parsed per-device
from the partitioned HLO text the same way. The three terms are therefore
directly comparable per-device seconds.

MODEL_FLOPS uses the 6·N·D convention (2·N·D for inference) with N =
active params, so the MODEL_FLOPS / HLO_FLOPs ratio exposes remat
recompute and attention/dispatch overheads.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Any, Dict

# TPU v5e hardware constants (per chip)
@dataclass(frozen=True)
class _HW:
    peak_flops: float = 197e12      # bf16
    hbm_bw: float = 819e9           # bytes/s
    link_bw: float = 50e9           # bytes/s per ICI link
    hbm_bytes: float = 16e9


HW = _HW()

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# one HLO instruction: "%name = TYPE opcode(OPERANDS...)," possibly fused
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, Any]:
    """Sum operand bytes of every collective op in (partitioned) HLO text."""
    per_op: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    counts: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        if " = " not in s:
            continue
        _, rhs = s.split(" = ", 1)
        m = re.match(r"(?:\([^)]*\)|\S+)\s+([\w-]+)\(", rhs)
        if not m:
            continue
        op = m.group(1)
        base = op
        for suffix in ("-start", "-done", "-update"):
            if base.endswith(suffix):
                base = base[: -len(suffix)]
        if base not in _COLLECTIVES:
            continue
        if op.endswith("-done"):  # operands counted on the -start op
            continue
        # operand shapes appear inline inside the call parens
        args = rhs[m.end():]
        depth = 1
        for i, ch in enumerate(args):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    args = args[:i]
                    break
        total = 0
        for dm in _SHAPE_RE.finditer(args):
            total += _shape_bytes(dm.group(1), dm.group(2))
        per_op[base] += total
        counts[base] += 1
    return {
        "bytes_by_type": per_op,
        "counts_by_type": counts,
        "total_bytes": sum(per_op.values()),
        "total_count": sum(counts.values()),
    }


def model_flops(cfg, cell) -> float:
    """6·N_active·tokens (train) / 2·N_active·tokens (inference).

    Enc-dec: encoder params see ``encoder_seq`` frames, decoder params the
    text sequence — counting all params × text tokens would overstate the
    useful FLOPs (the seamless ratio was >1 before this split).
    """
    _, active = cfg.param_count()
    mult = 6.0 if cell.kind == "train" else 2.0
    if cell.kind == "decode":
        dec_tokens = cell.global_batch
    else:
        dec_tokens = cell.global_batch * cell.seq_len
    if cfg.is_enc_dec:
        # split active params proportionally to layer counts
        enc_frac = cfg.n_encoder_layers / (cfg.n_encoder_layers + 2 * cfg.n_layers)
        enc_tokens = cell.global_batch * cfg.encoder_seq
        if cell.kind == "decode":
            enc_tokens = 0  # encoder ran at prefill
        return mult * active * (
            enc_frac * enc_tokens + (1 - enc_frac) * dec_tokens
        )
    return mult * active * dec_tokens


def analyze_compiled(compiled, cfg, cell, mesh) -> Dict[str, Any]:
    from . import hlo_parse

    chips = mesh.devices.size
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # older JAX wraps the dict in a list
        cost = cost[0] if cost else {}
    # XLA's cost_analysis counts while bodies once — recorded for reference
    raw_flops = float(cost.get("flops", 0.0))
    raw_bytes = float(cost.get("bytes accessed", 0.0))
    # trip-count-corrected per-device costs from the partitioned HLO
    parsed = hlo_parse.analyze(compiled.as_text())
    flops_dev = parsed["flops"]
    bytes_dev = parsed["hbm_bytes"]
    coll_dev = parsed["collective_wire_bytes"]

    compute_s = flops_dev / HW.peak_flops
    memory_s = bytes_dev / HW.hbm_bw
    collective_s = coll_dev / HW.link_bw
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    step_s = max(terms.values()) if terms else 0.0
    mflops = model_flops(cfg, cell)
    useful_ratio = mflops / max(flops_dev * chips, 1.0)
    mfu = mflops / max(chips * HW.peak_flops * step_s, 1e-30) if step_s else 0.0

    out = {
        "chips": chips,
        "hlo_flops_per_device": flops_dev,
        "hlo_bytes_per_device": bytes_dev,
        "collective_bytes_per_device": float(parsed["collective_bytes"]),
        "collective_wire_bytes_per_device": coll_dev,
        "collective_detail": {
            "bytes_by_type": parsed["collective_bytes_by_type"],
            "wire_bytes_by_type": parsed["collective_wire_bytes_by_type"],
            "counts_by_type": parsed["collective_counts_by_type"],
            "total_count": parsed["collective_count"],
        },
        "xla_cost_analysis_raw": {"flops": raw_flops, "bytes": raw_bytes},
        "compute_term_s": compute_s,
        "memory_term_s": memory_s,
        "collective_term_s": collective_s,
        "dominant": dominant,
        "model_flops": mflops,
        "useful_flops_ratio": useful_ratio,
        "roofline_fraction": mfu,
    }
    # theoretical per-device bandwidth floor: every step must at least
    # read the (sharded) weights once; decode additionally streams the
    # cache. Distance to this floor is the §Perf target for decode cells.
    total_params, _ = cfg.param_count()
    floor_bytes = total_params * 2.0 / chips  # bf16 weights
    if cell.kind == "decode":
        m = min(cell.seq_len, cfg.swa_window) if cfg.swa_window else cell.seq_len
        if cfg.family in ("dense", "moe", "vlm", "audio"):
            kvb = (
                cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
                if cfg.mla
                else 2 * cfg.n_kv_heads * cfg.head_dim_
            )
            floor_bytes += cell.global_batch * m * kvb * 2.0 * cfg.n_layers / chips
    out["memory_floor_s"] = floor_bytes / HW.hbm_bw
    # kernel-adjusted view: named_scope traffic → Pallas kernel boundary
    adj = kernel_adjusted(
        {"hbm_bytes": bytes_dev, "hbm_by_kernel_scope": parsed["hbm_by_kernel_scope"]},
        cfg, cell, chips,
    )
    mem_k = adj["memory_term_kernel_s"]
    step_k = max(compute_s, mem_k, collective_s)
    terms_k = {"compute": compute_s, "memory": mem_k, "collective": collective_s}
    out.update(
        kernel_adjusted=adj,
        memory_term_kernel_s=mem_k,
        dominant_kernel=max(terms_k, key=terms_k.get),
        roofline_fraction_kernel=(
            mflops / max(chips * HW.peak_flops * step_k, 1e-30) if step_k else 0.0
        ),
    )
    return out


# =====================================================================
# Kernel-adjusted roofline
#
# The pure-jnp reference paths materialize attention scores / SSD chunk
# tensors in HBM; the Pallas kernels (repro.kernels) keep those tiles in
# VMEM on TPU. Model code tags kernel-eligible regions with
# jax.named_scope("kernel_*"); the parser measures their HLO HBM bytes,
# and here we substitute each scope's traffic with the *kernel boundary*
# (q/k/v/o etc. — what the kernel actually DMAs), giving the adjusted
# memory term the TPU deployment would see.
# =====================================================================

_PASS_FACTOR = {"train": 4.0, "prefill": 1.0, "decode": 1.0}
# train: fwd + remat-fwd + backward (reads q,k,v,o,do; writes dq,dk,dv) ≈ 4×


def kernel_boundary_bytes(cfg, cell) -> Dict[str, float]:
    """GLOBAL bytes per step each Pallas kernel would move, by scope."""
    B, S = cell.global_batch, cell.seq_len
    fam = cfg.family
    H, KV, hd, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_, cfg.d_model
    f = _PASS_FACTOR[cell.kind]
    out: Dict[str, float] = {}

    def flash(n_calls, sq, sk, h_q, kv, d_qk, d_v):
        # q + o (H-headed) and k + v (kv-headed), bf16
        return n_calls * f * 2.0 * (
            sq * h_q * (d_qk + d_v) + sk * kv * (d_qk + d_v)
        ) * B

    if fam in ("dense", "moe", "vlm", "audio", "hybrid"):
        sq = 1 if cell.kind == "decode" else S
        if cell.kind == "decode":
            # decode uses the decode-attention kernel over the cache
            m = min(S, cfg.swa_window) if cfg.swa_window else S
            if cfg.mla is None:
                n_layers = {
                    "dense": cfg.n_layers,
                    "moe": cfg.n_layers,
                    "vlm": cfg.n_layers - cfg.n_layers // max(cfg.cross_attn_every, 1),
                    "audio": cfg.n_layers,
                    "hybrid": (cfg.n_layers // cfg.shared_attn_every)
                    if cfg.shared_attn_every
                    else 0,
                }[fam]
                out["kernel_decode_attn"] = n_layers * 2.0 * B * m * KV * hd * 2.0
            # cross-attn decode (vlm/audio) flows through the flash scope
            if fam == "vlm":
                n_cross = cfg.n_layers // cfg.cross_attn_every
                out["kernel_flash_attn"] = flash(
                    n_cross, 1, cfg.num_image_tokens, H, KV, hd, hd
                )
            if fam == "audio":
                out["kernel_flash_attn"] = flash(
                    cfg.n_layers, 1, cfg.encoder_seq, H, KV, hd, hd
                )
        else:
            if cfg.mla is not None:
                m = cfg.mla
                d_qk = m.qk_nope_head_dim + m.qk_rope_head_dim
                # expanded k/v are H-headed at the kernel boundary
                out["kernel_flash_attn"] = flash(
                    cfg.n_layers, sq, S, H, H, d_qk, m.v_head_dim
                )
            elif fam == "dense" or fam == "moe":
                out["kernel_flash_attn"] = flash(cfg.n_layers, sq, S, H, KV, hd, hd)
            elif fam == "vlm":
                n_cross = cfg.n_layers // cfg.cross_attn_every
                n_self = cfg.n_layers - n_cross
                out["kernel_flash_attn"] = flash(n_self, sq, S, H, KV, hd, hd) + flash(
                    n_cross, sq, cfg.num_image_tokens, H, KV, hd, hd
                )
            elif fam == "audio":
                enc = flash(cfg.n_encoder_layers, cfg.encoder_seq, cfg.encoder_seq, H, KV, hd, hd)
                dec = flash(cfg.n_layers, sq, S, H, KV, hd, hd)
                cross = flash(cfg.n_layers, sq, cfg.encoder_seq, H, KV, hd, hd)
                out["kernel_flash_attn"] = enc + dec + cross
            elif fam == "hybrid":
                n_sh = cfg.n_layers // cfg.shared_attn_every if cfg.shared_attn_every else 0
                out["kernel_flash_attn"] = flash(n_sh, sq, S, H, KV, hd, hd)

    if fam == "hybrid" and cell.kind != "decode":
        s = cfg.ssm
        di, nh, N = s.d_inner(D), s.n_heads(D), s.d_state
        per = B * S * (di * 2 + nh * 4 + 2 * N * 2 + di * 4)  # x,dt,B,C,y
        out["kernel_ssd_scan"] = cfg.n_layers * f * float(per)
    if fam == "ssm" and cell.kind != "decode":
        x = cfg.xlstm
        inner = int(x.mlstm_proj_factor * D)
        nh = cfg.n_heads
        n_s = cfg.n_layers // x.slstm_every if x.slstm_every else 0
        n_m = cfg.n_layers - n_s
        per = B * S * (3 * inner * 2 + 2 * nh * 4 + inner * 4)  # q,k,v,i,f,y
        out["kernel_mlstm_scan"] = n_m * f * float(per)
    return out


def kernel_adjusted(rec: Dict[str, Any], cfg, cell, chips: int) -> Dict[str, Any]:
    """Adjusted memory term: measured scope traffic → kernel boundary."""
    scopes = rec.get("hbm_by_kernel_scope") or {}
    boundary = kernel_boundary_bytes(cfg, cell)
    measured = sum(scopes.values())
    replaced = sum(boundary.get(k, 0.0) / chips for k in scopes)
    adj_bytes = max(rec["hbm_bytes"] - measured + replaced, 0.0)
    return {
        "scope_bytes_measured": {k: float(v) for k, v in scopes.items()},
        "kernel_boundary_bytes_per_device": {
            k: v / chips for k, v in boundary.items()
        },
        "hbm_bytes_adjusted": adj_bytes,
        "memory_term_kernel_s": adj_bytes / HW.hbm_bw,
    }
