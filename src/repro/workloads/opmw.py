"""OPMW-like synthetic workflow collection (paper §5.1).

Structure: G source groups, each with a shared prefix *chain* of abstract
tasks (the paper's Fig. 1 pattern — members of a group reuse nested
prefixes); each DAG appends a unique suffix whose task types are drawn
from a global pool with replacement (same type, different ancestry ⇒
type-similar but NOT equivalent — this is why the paper's 219 unique
abstract tasks still need ≈274 running tasks).

Calibrated (seed=7) to: 35 DAGs, 471 task instances, ~219 unique abstract
tasks, ~270 equivalence classes, sizes within 2–38.
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.api.builder import flow
from repro.core.graph import Dataflow

N_DAGS = 35
TOTAL_TASKS = 471
N_GROUPS = 6
SUFFIX_POOL = 520
SINK_TYPES = 5


def opmw_workload(seed: int = 7) -> List[Dataflow]:
    rng = np.random.default_rng(seed)
    # group membership: 6 groups over 35 DAGs, ≥3 members each
    sizes = [8, 7, 6, 6, 4, 4]
    assert sum(sizes) == N_DAGS
    # shared prefix chain lengths per group
    chain_len = [9, 9, 8, 8, 7, 7]

    dags: List[Dataflow] = []
    # prefix depth for each DAG: mostly deep (encourages nesting reuse)
    depths: List[int] = []
    groups: List[int] = []
    for g, n in enumerate(sizes):
        for _ in range(n):
            depths.append(int(rng.integers(chain_len[g] // 2, chain_len[g] + 1)))
            groups.append(g)
    depths[0] = 0  # the paper's 2-task DAG (source → sink)
    # suffix lengths: meet the exact total
    #   total = Σ (1 src + depth + suffix + 1 sink)
    base = N_DAGS * 2 + sum(depths)
    suffix_total = TOTAL_TASKS - base
    assert suffix_total > 0
    raw = rng.dirichlet(np.ones(N_DAGS) * 1.2) * suffix_total
    suffix = np.maximum(np.round(raw).astype(int), 0)
    # exact adjustment + per-DAG max size 38
    while suffix.sum() != suffix_total:
        i = int(rng.integers(N_DAGS))
        if suffix.sum() < suffix_total:
            suffix[i] += 1
        elif suffix[i] > 0:
            suffix[i] -= 1
    suffix[0] = 0  # keep the 2-task DAG minimal
    # one 38-task DAG (the paper's max)
    big = 1
    grow = 38 - 2 - depths[big] - suffix[big]
    suffix[big] += grow
    donors = [i for i in range(N_DAGS) if i not in (0, big)]
    while grow > 0:
        j = donors[int(rng.integers(len(donors)))]
        if suffix[j] > 0:
            suffix[j] -= 1
            grow -= 1
    for i in range(N_DAGS):
        cap = 38 - 2 - depths[i]
        while suffix[i] > cap:
            j = int(rng.integers(N_DAGS))
            if j not in (0, big) and suffix[j] < 38 - 2 - depths[j]:
                suffix[i] -= 1
                suffix[j] += 1

    for i in range(N_DAGS):
        g = groups[i]
        d = depths[i]
        name = f"opmw{i:02d}"
        b = flow(name).source(f"opmw-src-{g}")
        for k in range(d):
            # shared prefix task: type+config identical across the group
            b.then(f"g{g}.step{k}", stage=k)
        for k in range(int(suffix[i])):
            b.then(f"op{int(rng.integers(SUFFIX_POOL))}")
        b.sink(f"store{int(rng.integers(SINK_TYPES))}")
        dags.append(b.build())
    assert sum(len(d) for d in dags) == TOTAL_TASKS
    return dags


def workload_stats(dags: List[Dataflow]) -> dict:
    from repro.core.signatures import compute_signatures

    total = sum(len(d) for d in dags)
    abstract = {(t.type, t.config) for d in dags for t in d.tasks.values()}
    classes = set()
    for d in dags:
        sigs = compute_signatures(d)
        classes |= set(sigs.values())
    sizes = [len(d) for d in dags]
    return {
        "dags": len(dags),
        "total_tasks": total,
        "unique_abstract": len(abstract),
        "equiv_classes": len(classes),
        "min_size": min(sizes),
        "max_size": max(sizes),
    }
