"""Submission/removal traces (paper §5.1) and trace replay over the API."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Iterable, Iterator, List, Tuple

import numpy as np

from repro.core.graph import Dataflow


@dataclass(frozen=True)
class TraceEvent:
    op: str  # "add" | "remove"
    name: str


def replay(
    session, dags: Iterable[Dataflow], events: Iterable[TraceEvent]
) -> Iterator[Tuple[TraceEvent, Any]]:
    """Drive a :class:`repro.api.ReuseSession` through a trace.

    Yields ``(event, receipt)`` after each step so callers can sample
    point-in-time metrics (Fig. 2/3/4 accounting); lifecycle hooks on the
    session observe merges/unmerges as they happen.
    """
    by_name = {d.name: d for d in dags}
    for ev in events:
        if ev.op == "add":
            yield ev, session.submit(by_name[ev.name].copy())
        elif ev.op == "remove":
            yield ev, session.remove(ev.name)
        else:
            raise ValueError(f"unknown trace op {ev.op!r}")


def seq_trace(dags: List[Dataflow], seed: int = 0) -> List[TraceEvent]:
    """Sequential Submit/Drain: add all (uniform, without replacement),
    then remove all in (a different) random order — 2·N steps."""
    rng = np.random.default_rng(seed)
    names = [d.name for d in dags]
    add = list(rng.permutation(names))
    drain = list(rng.permutation(names))
    return [TraceEvent("add", n) for n in add] + [TraceEvent("remove", n) for n in drain]


def rw_trace(
    dags: List[Dataflow],
    seed: int = 1,
    steps: int = 100,
    init: int | None = None,
) -> List[TraceEvent]:
    """Random Walk: preload ≈⅔ of the workload, then `steps` add/remove
    coin flips, then drain. A submitted DAG is never resubmitted while
    present (paper §5.1)."""
    rng = np.random.default_rng(seed)
    names = [d.name for d in dags]
    if init is None:
        init = (2 * len(names)) // 3
    preload = list(rng.permutation(names)[:init])
    events = [TraceEvent("add", n) for n in preload]
    present = set(preload)
    absent = [n for n in names if n not in present]
    for _ in range(steps):
        do_add = bool(rng.random() < 0.5)
        if do_add and absent:
            n = absent.pop(int(rng.integers(len(absent))))
            present.add(n)
            events.append(TraceEvent("add", n))
        elif present:
            # sorted() so the draw is a pure function of the seed — set
            # iteration order varies with PYTHONHASHSEED across processes.
            n = sorted(present)[int(rng.integers(len(present)))]
            present.discard(n)
            absent.append(n)
            events.append(TraceEvent("remove", n))
    for n in list(rng.permutation(sorted(present))):
        events.append(TraceEvent("remove", n))
    return events
