"""Workload generators + traces matching the paper §5.1.

The OPMW portal data is not shipped; the generators are seeded and
calibrated to the *published statistics*:

  OPMW: 35 DAGs, 471 total tasks, 219 unique abstract tasks, 2–38
        tasks/DAG, π task logic, shared prefix structure.
  RIoT: 21 DAGs, 138 total tasks, 19 distinct task types, 4–8 tasks/DAG,
        3 IoT sources (Smart Grid / Urban / Taxi), real task logic.

Traces (§5.1): SEQ (submit all in random order, then drain) and two
Random Walks (add/remove p=½ ×100 after a ⅔ preload, then drain).
"""
from .opmw import opmw_workload
from .riot import riot_workload
from .tenants import TenantEvent, tenant_copy, tenant_trace
from .traces import TraceEvent, replay, rw_trace, seq_trace

__all__ = [
    "opmw_workload",
    "riot_workload",
    "replay",
    "seq_trace",
    "rw_trace",
    "TraceEvent",
    "TenantEvent",
    "tenant_copy",
    "tenant_trace",
]
