"""RIoTBench-style IoT application collection (paper §5.1).

21 dataflows with *real* task logic (repro.ops.riot) over the 3 IoT
sources: 7 application variants per source — ETL, two STATS variants,
distinct-count, two predictive-analytics variants, and a short ETL —
sharing the senml-parse → range-filter → bloom-filter prefix and parts of
the mid-chain (the window op is shared by both STATS variants, the
interpolate by ETL and both PRED variants — real cross-app reuse, not
just prefix nesting).

Calibrated to: 21 DAGs, 138 total tasks, 19 distinct task types, sizes
4–8, ≈75 equivalence classes (the paper's Reuse peak).
"""
from __future__ import annotations

from typing import List

from repro.api.builder import flow
from repro.core.graph import Dataflow

SOURCES = ("urban", "meter", "taxi")


def _chain(name: str, src_type: str, steps, sink_type: str = "store") -> Dataflow:
    b = flow(name).source(src_type)
    for typ, cfg in steps:
        b.then(typ, **cfg)
    return b.sink(sink_type).build()


def riot_workload(seed: int = 0) -> List[Dataflow]:
    dags: List[Dataflow] = []
    for s, src in enumerate(SOURCES):
        pre = [
            ("senml_parse", {"schema": src}),
            ("range_filter", {"lo": -100 + s, "hi": 100 + s}),
            ("bloom_filter", {"bits": 1024}),
        ]
        pred_pre = [
            ("csv_parse", {"cols": 5 + s}),
            ("range_filter", {"lo": -50, "hi": 50}),
        ]
        interp = ("interpolate", {"k": 2})
        win = ("win", {"w": 16})
        # 1. ETL (8): parse prefix + interpolate + annotate + kalman
        dags.append(
            _chain(
                f"{src}_etl", src,
                pre + [interp, ("annotate", {"meta": src}), ("kalman", {"q": 0.5})],
            )
        )
        # 2. STATS-average (7): shares the window op with #3
        dags.append(_chain(f"{src}_stats_avg", src, pre + [win, ("avg", {"n": 8})]))
        # 3. STATS-moment (8): shares the window op with #2
        dags.append(
            _chain(f"{src}_stats_mom", src, pre + [win, ("moment2", {}), ("sliding_linreg", {"w": 8})])
        )
        # 4. distinct count (6)
        dags.append(_chain(f"{src}_distinct", src, pre + [("distinct_count", {"h": 4})]))
        # 5. PRED linear regression (7): csv prefix, shares interp with #6
        dags.append(
            _chain(
                f"{src}_pred_lr", src,
                pred_pre + [interp, ("linreg", {"d": 4}), ("error_estimate", {})],
            )
        )
        # 6. PRED decision tree (6)
        dags.append(_chain(f"{src}_pred_dt", src, pred_pre + [interp, ("dtree", {"depth": 3})]))
        # 7. short Kalman smoothing (4): shares only the senml parse
        dags.append(
            _chain(
                f"{src}_kalman", src,
                [("senml_parse", {"schema": src}), ("kalman", {"q": 0.1})],
            )
        )
    total = sum(len(d) for d in dags)
    assert total == 138, total
    return dags
