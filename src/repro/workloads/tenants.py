"""Multi-tenant submission traces for the serving front end.

Extends the §5.1 single-client traces to the serving setting: several
tenants independently churning submissions drawn from one shared dataflow
pool. Because tenants draw from the *same* pool, their running sets
overlap heavily — exactly the regime where slot-based admission with
reuse (new segments only) admits far more work than a reuse-blind pool.

Names are tenant-namespaced (``alice/opmw-03``) so the same pool DAG can
run for several tenants at once; :func:`tenant_copy` builds the renamed
:class:`~repro.core.graph.Dataflow` (task ids are per-submission, so they
need no renaming).

The trace is a **lazy generator** — a million-event trace costs O(1)
memory — and is a pure function of its arguments (seeded generator,
sorted draws), so benchmark and conformance runs replay identically.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Sequence

import numpy as np

from repro.core.graph import Dataflow


@dataclass(frozen=True)
class TenantEvent:
    tenant: str
    op: str  # "add" | "remove"
    name: str  # tenant-namespaced submission name ("alice/opmw-03")
    pool_name: str  # the pool dataflow it instantiates


def tenant_copy(df: Dataflow, tenant: str) -> Dataflow:
    """The tenant's instance of a pool dataflow: same graph, namespaced name."""
    return df.copy(f"{tenant}/{df.name}")


def tenant_trace(
    dags: Sequence[Dataflow],
    tenants: Sequence[str] = ("alice", "bob"),
    *,
    events: int = 1000,
    weights: Optional[Dict[str, float]] = None,
    p_remove: float = 0.4,
    seed: int = 11,
) -> Iterator[TenantEvent]:
    """Yield ``events`` add/remove events across ``tenants``.

    Each event first draws a tenant (probability proportional to
    ``weights``, default uniform), then flips a ``p_remove`` coin: remove
    a uniformly-drawn present submission of that tenant, or add a
    uniformly-drawn pool dataflow the tenant isn't currently running. A
    tenant with nothing present always adds; one running the whole pool
    always removes.
    """
    if not dags:
        raise ValueError("tenant_trace needs a non-empty dataflow pool")
    if not tenants:
        raise ValueError("tenant_trace needs at least one tenant")
    if not 0.0 <= p_remove < 1.0:
        raise ValueError(f"p_remove must be in [0, 1), got {p_remove}")
    rng = np.random.default_rng(seed)
    names = [d.name for d in dags]
    w = np.array([float((weights or {}).get(t, 1.0)) for t in tenants])
    if (w <= 0).any():
        raise ValueError("tenant weights must be positive")
    w = w / w.sum()
    # Per-tenant state as sorted lists so draws are a pure function of the
    # seed (set iteration order varies with PYTHONHASHSEED).
    present: Dict[str, List[str]] = {t: [] for t in tenants}
    absent: Dict[str, List[str]] = {t: list(names) for t in tenants}
    for _ in range(events):
        tenant = tenants[int(rng.choice(len(tenants), p=w))]
        do_remove = bool(rng.random() < p_remove)
        if (do_remove and present[tenant]) or not absent[tenant]:
            pool_name = present[tenant].pop(int(rng.integers(len(present[tenant]))))
            absent[tenant].append(pool_name)
            op = "remove"
        else:
            pool_name = absent[tenant].pop(int(rng.integers(len(absent[tenant]))))
            present[tenant].append(pool_name)
            op = "add"
        yield TenantEvent(
            tenant=tenant, op=op, name=f"{tenant}/{pool_name}", pool_name=pool_name
        )
