"""Worker-health events emitted by the cluster plane.

Every observable lifecycle transition in the supervised worker pool —
a missed heartbeat, a respawn, a segment redeploy, a pool resize — is
recorded as a :class:`WorkerEvent`. The multiproc backend keeps a bounded
ring of recent events (``backend.worker_events``) and forwards each one
to the user hook installed via ``StreamSystem(on_worker_event=...)``;
the serving front end surfaces the tail through ``status()``/``stats()``.

Kept dependency-free so the coordinator, the supervisor thread and the
serve layer can all import it without touching JAX or the worker plane.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass
from typing import Any, Dict, Optional

# -- event kinds -----------------------------------------------------------------
HEARTBEAT_MISSED = "heartbeat-missed"  # liveness probe failed / process gone
WORKER_DEAD = "worker-dead"            # crash detected (pipe EOF or probe)
WORKER_HUNG = "worker-hung"            # RPC exceeded the hang timeout
WORKER_RESPAWNED = "worker-respawned"  # fresh process launched in its slot
SEGMENT_REDEPLOYED = "segment-redeployed"  # segment rebuilt from snapshot
POOL_GROWN = "pool-grown"              # resize_pool added workers
POOL_SHRUNK = "pool-shrunk"            # resize_pool retired workers
SCALE_UP = "scale-up"                  # autoscaler decided to grow
SCALE_DOWN = "scale-down"              # autoscaler decided to shrink

EVENT_KINDS = (
    HEARTBEAT_MISSED,
    WORKER_DEAD,
    WORKER_HUNG,
    WORKER_RESPAWNED,
    SEGMENT_REDEPLOYED,
    POOL_GROWN,
    POOL_SHRUNK,
    SCALE_UP,
    SCALE_DOWN,
)


@dataclass(frozen=True)
class WorkerEvent:
    """One cluster-plane health event.

    ``step`` is the coordinator's step counter when the event fired,
    ``worker`` the pool slot it concerns (``None`` for pool-wide events),
    ``ms`` how long the transition took where that is meaningful
    (recovery latency, resize latency)."""

    kind: str
    worker: Optional[int] = None
    step: int = 0
    detail: str = ""
    ms: float = 0.0

    def to_dict(self) -> Dict[str, Any]:
        return asdict(self)
