"""Cluster plane: worker supervision, crash recovery, elastic autoscaling
and pluggable worker launchers over the multiproc data plane.

Imports resolve lazily (PEP 562) because :mod:`repro.runtime.worker`
imports :mod:`repro.cluster.events` at module load — an eager
``from .supervisor import WorkerSupervisor`` here would close that loop.
:mod:`~repro.cluster.events` itself is dependency-free and safe to import
from anywhere.
"""
from __future__ import annotations

import importlib
from typing import TYPE_CHECKING

from .events import EVENT_KINDS, WorkerEvent

# name -> (module, attribute); resolved on first access to avoid the
# worker.py <-> cluster import cycle and keep `import repro.cluster` light.
_LAZY = {
    "WorkerSupervisor": ("repro.cluster.supervisor", "WorkerSupervisor"),
    "Autoscaler": ("repro.cluster.autoscaler", "Autoscaler"),
    "AutoscalePolicy": ("repro.cluster.autoscaler", "AutoscalePolicy"),
    "WorkerHandle": ("repro.cluster.launcher", "WorkerHandle"),
    "LocalProcessLauncher": ("repro.cluster.launcher", "LocalProcessLauncher"),
    "SubprocessLauncher": ("repro.cluster.launcher", "SubprocessLauncher"),
    "resolve_launcher": ("repro.cluster.launcher", "resolve_launcher"),
}

if TYPE_CHECKING:  # pragma: no cover - static imports for type checkers
    from .autoscaler import Autoscaler, AutoscalePolicy
    from .launcher import (
        LocalProcessLauncher,
        SubprocessLauncher,
        WorkerHandle,
        resolve_launcher,
    )
    from .supervisor import WorkerSupervisor

__all__ = [
    "Autoscaler",
    "AutoscalePolicy",
    "EVENT_KINDS",
    "LocalProcessLauncher",
    "SubprocessLauncher",
    "WorkerEvent",
    "WorkerHandle",
    "WorkerSupervisor",
    "resolve_launcher",
]


def __getattr__(name: str):
    try:
        module, attr = _LAZY[name]
    except KeyError:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}") from None
    value = getattr(importlib.import_module(module), attr)
    globals()[name] = value  # cache for subsequent lookups
    return value
