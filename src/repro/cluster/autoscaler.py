"""EWMA-driven autoscaling of the multiproc worker pool.

The straggler tracker already aggregates per-worker EWMA step-times
(``device_ewma()``) to drive ``ewma_aware`` migration; the autoscaler
reads the *same* pressure signal to resize the pool itself. Pressure is
the mean per-worker aggregate EWMA — "milliseconds of segment compute
each worker carries per step". Sustained pressure above ``high_ms``
grows the pool, sustained idling below ``low_ms`` shrinks it, with
hysteresis (``patience`` consecutive observations) and a ``cooldown``
between actions so migration churn from one resize never triggers the
next.

:class:`AutoscalePolicy` is the pure decision function (unit-testable,
no backend); :class:`Autoscaler` binds it to a backend and feeds it one
observation per step (``StreamSystem`` calls :meth:`Autoscaler.observe`
after every ``step()`` when ``autoscale=`` is on).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from .events import SCALE_DOWN, SCALE_UP


@dataclass
class AutoscalePolicy:
    """Hysteresis-banded threshold policy over per-worker pressure.

    ``decide`` returns the target pool size — equal to ``n_workers``
    when no action is warranted. Scaling steps by one worker at a time:
    resize migrates state, so conservative moves keep churn bounded and
    let the next observations confirm the trend before moving again."""

    min_workers: int = 1
    max_workers: int = 4
    high_ms: float = 50.0   # grow when mean per-worker pressure exceeds this
    low_ms: float = 5.0     # shrink when it stays below this
    patience: int = 3       # consecutive observations before acting
    cooldown: int = 5       # observations to ignore after an action
    _high_streak: int = field(default=0, repr=False)
    _low_streak: int = field(default=0, repr=False)
    _cooling: int = field(default=0, repr=False)

    def __post_init__(self) -> None:
        if self.min_workers < 1:
            raise ValueError("min_workers must be >= 1")
        if self.max_workers < self.min_workers:
            raise ValueError("max_workers must be >= min_workers")
        if self.low_ms >= self.high_ms:
            raise ValueError("low_ms must be < high_ms (hysteresis band)")

    def decide(self, pressure_ms: float, n_workers: int) -> int:
        if self._cooling > 0:
            self._cooling -= 1
            return n_workers
        if pressure_ms > self.high_ms:
            self._high_streak += 1
            self._low_streak = 0
        elif pressure_ms < self.low_ms:
            self._low_streak += 1
            self._high_streak = 0
        else:
            self._high_streak = self._low_streak = 0
        if self._high_streak >= self.patience and n_workers < self.max_workers:
            self._high_streak = self._low_streak = 0
            self._cooling = self.cooldown
            return n_workers + 1
        if self._low_streak >= self.patience and n_workers > self.min_workers:
            self._high_streak = self._low_streak = 0
            self._cooling = self.cooldown
            return n_workers - 1
        return n_workers


class Autoscaler:
    """Bind an :class:`AutoscalePolicy` to a resizable worker backend."""

    def __init__(self, backend: Any, policy: Optional[AutoscalePolicy] = None,
                 **policy_kwargs: Any):
        if not hasattr(backend, "resize_pool"):
            raise ValueError(
                "autoscaling requires a resizable worker pool "
                f"(backend={getattr(backend, 'name', backend)!r} has no "
                "resize_pool); use backend='multiproc'"
            )
        if policy is not None and policy_kwargs:
            raise ValueError("pass either a policy instance or its kwargs, not both")
        self.backend = backend
        self.policy = policy or AutoscalePolicy(**policy_kwargs)
        self.actions: List[Dict[str, Any]] = []

    def pressure(self) -> float:
        """Mean per-worker aggregate EWMA step-time (ms) — the same signal
        that drives ``ewma_aware`` placement migration."""
        ewma = self.backend.device_ewma()
        n = max(self.backend.n_workers, 1)
        return sum(ewma.values()) / n

    def observe(self, report: Optional[Any] = None) -> Optional[int]:
        """One post-step observation; resizes the pool when the policy
        says so. Returns the new pool size, or ``None`` if unchanged."""
        pressure = self.pressure()
        n = self.backend.n_workers
        target = self.policy.decide(pressure, n)
        if target == n:
            return None
        kind = SCALE_UP if target > n else SCALE_DOWN
        self.backend._emit_worker_event(
            kind, detail=f"pressure={pressure:.3f}ms {n}->{target} workers"
        )
        self.backend.resize_pool(target)
        self.actions.append({
            "step": self.backend.step_count,
            "pressure_ms": pressure,
            "from": n,
            "to": target,
        })
        return target

    def state(self) -> Dict[str, Any]:
        return {
            "workers": self.backend.n_workers,
            "min_workers": self.policy.min_workers,
            "max_workers": self.policy.max_workers,
            "high_ms": self.policy.high_ms,
            "low_ms": self.policy.low_ms,
            "pressure_ms": self.pressure(),
            "actions": list(self.actions),
        }
