"""Worker launchers — how the multiproc coordinator gets a worker process.

PR 5 hard-wired ``multiprocessing``: coordinator and workers shared one
host, one Python, one pipe implementation. This module lifts that into a
pluggable :class:`WorkerLauncher` seam so the supervisor can respawn dead
workers through the same code path that spawned them, and so the pool can
span hosts:

  * :class:`LocalProcessLauncher` — the PR-5 behaviour: a ``spawn``-start
    :mod:`multiprocessing` child connected by a duplex pipe. Default.
  * :class:`SubprocessLauncher` — ssh-shaped remote launch. The worker is
    started as ``prefix + [python, -m, repro.cluster.launcher, --connect
    host:port, --token t]`` and dials back to the coordinator; the worker
    command pipe then runs over that TCP socket using the same
    length-prefixed JSON framing as the ``tcp`` stream transport
    (:func:`~repro.runtime.transport._send_msg`). With
    ``command_prefix=["ssh", "node7"]`` the process lands on another host
    — pair it with ``transport="tcp"`` so the data plane spans hosts too.

Both return a :class:`WorkerHandle`: the command connection plus the
process-lifecycle surface (``is_alive`` / ``terminate`` / ``join``) the
supervisor needs for crash detection and forced respawns.
"""
from __future__ import annotations

import os
import secrets
import select
import socket
import subprocess
import sys
import threading
from typing import Any, Dict, List, Optional, Sequence, Union

from repro.runtime.transport import _recv_msg, _send_msg


class WorkerHandle:
    """Conn + lifecycle of one launched worker (duck-typed per launcher)."""

    conn: Any
    pid: Optional[int]

    def is_alive(self) -> bool:  # pragma: no cover - interface
        raise NotImplementedError

    def terminate(self) -> None:  # pragma: no cover - interface
        raise NotImplementedError

    def join(self, timeout: Optional[float] = None) -> None:  # pragma: no cover
        raise NotImplementedError

    def close(self) -> None:
        try:
            self.conn.close()
        except Exception:
            pass


class _MpHandle(WorkerHandle):
    def __init__(self, proc, conn):
        self.proc = proc
        self.conn = conn
        self.pid = proc.pid

    def is_alive(self) -> bool:
        return self.proc.is_alive()

    def terminate(self) -> None:
        self.proc.kill()

    def join(self, timeout: Optional[float] = None) -> None:
        self.proc.join(timeout=timeout)


class _PopenHandle(WorkerHandle):
    def __init__(self, proc: subprocess.Popen, conn: "SocketPipe"):
        self.proc = proc
        self.conn = conn
        self.pid = proc.pid

    def is_alive(self) -> bool:
        return self.proc.poll() is None

    def terminate(self) -> None:
        self.proc.kill()

    def join(self, timeout: Optional[float] = None) -> None:
        try:
            self.proc.wait(timeout=timeout)
        except subprocess.TimeoutExpired:
            pass


class SocketPipe:
    """``multiprocessing.Connection``-shaped wrapper over a TCP socket.

    Messages are JSON dicts in the tcp transport's wire framing (u32
    header length + JSON), so the worker pipe protocol crosses hosts with
    the exact machinery the data plane already trusts. ``recv`` raises
    :class:`EOFError` on a closed peer — matching pipe semantics, so the
    coordinator's dead-worker detection works unchanged."""

    def __init__(self, sock: socket.socket):
        self._sock = sock
        self._send_lock = threading.Lock()
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)

    def send(self, obj: Dict[str, Any]) -> None:
        with self._send_lock:
            _send_msg(self._sock, obj)

    def recv(self) -> Dict[str, Any]:
        try:
            header, _ = _recv_msg(self._sock)
        except (ConnectionError, OSError) as e:
            raise EOFError(str(e)) from e
        return header

    def poll(self, timeout: float = 0.0) -> bool:
        try:
            ready, _, _ = select.select([self._sock], [], [], timeout)
        except OSError:
            return True  # closed socket: recv will raise EOFError promptly
        return bool(ready)

    def close(self) -> None:
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self._sock.close()


class LocalProcessLauncher:
    """Spawn workers as local ``multiprocessing`` children (PR-5 plane)."""

    name = "local"
    # workers share the coordinator's filesystem -> spill snapshots work
    supports_spill = True

    def __init__(self):
        import multiprocessing as mp

        # spawn, not fork: forking a JAX-initialized parent is unsafe
        self._ctx = mp.get_context("spawn")

    def launch(self, worker_id: int, transport_spec: Dict[str, Any],
               plane: str, log_path: str) -> WorkerHandle:
        from repro.runtime.worker import _worker_main

        parent_conn, child_conn = self._ctx.Pipe()
        proc = self._ctx.Process(
            target=_worker_main,
            args=(child_conn, worker_id, transport_spec, plane, log_path),
            name=f"repro-worker-{worker_id}",
            daemon=True,
        )
        proc.start()
        child_conn.close()
        return _MpHandle(proc, parent_conn)


class SubprocessLauncher:
    """Launch workers as subprocesses that dial back over TCP (ssh-shaped).

    ``command_prefix`` is prepended to the worker command line — empty for
    a plain local subprocess, ``["ssh", "nodeN"]`` (or a container exec)
    to land the worker elsewhere. The remote side needs ``repro`` on its
    ``PYTHONPATH`` (exported automatically for local subprocesses) and
    network reach back to ``connect_host``; the stream transport must be
    one that spans processes by address (``tcp``) when hosts differ.
    """

    name = "subprocess"

    def __init__(
        self,
        command_prefix: Sequence[str] = (),
        python: Optional[str] = None,
        connect_host: str = "127.0.0.1",
        accept_timeout: float = 30.0,
    ):
        # a plain subprocess shares this host's filesystem; an ssh/container
        # prefix lands the worker where coordinator-side spill reads fail
        self.supports_spill = not command_prefix
        self.command_prefix = list(command_prefix)
        self.python = python or sys.executable
        self.connect_host = connect_host
        self.accept_timeout = accept_timeout

    def launch(self, worker_id: int, transport_spec: Dict[str, Any],
               plane: str, log_path: str) -> WorkerHandle:
        server = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        server.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        server.bind((self.connect_host if not self.command_prefix else "0.0.0.0", 0))
        server.listen(1)
        port = server.getsockname()[1]
        token = secrets.token_hex(16)
        cmd = self.command_prefix + [
            self.python, "-m", "repro.cluster.launcher",
            "--connect", f"{self.connect_host}:{port}", "--token", token,
        ]
        env = dict(os.environ)
        if not self.command_prefix:
            # local subprocess: make sure the child finds this repro tree
            # (namespace package: __file__ is None, __path__ still points in)
            import repro

            pkg_dir = (
                os.path.dirname(repro.__file__)
                if getattr(repro, "__file__", None)
                else next(iter(repro.__path__))
            )
            src = os.path.dirname(os.path.abspath(pkg_dir))
            env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        proc = subprocess.Popen(cmd, env=env)
        server.settimeout(self.accept_timeout)
        try:
            sock, _ = server.accept()
        except socket.timeout:
            proc.kill()
            raise TimeoutError(
                f"worker {worker_id} did not dial back within "
                f"{self.accept_timeout}s (cmd: {' '.join(cmd)})"
            ) from None
        finally:
            server.close()
        pipe = SocketPipe(sock)
        hello = pipe.recv()
        if hello.get("token") != token:
            pipe.close()
            proc.kill()
            raise ConnectionError(f"worker {worker_id} dial-back token mismatch")
        pipe.send({
            "worker_id": worker_id,
            "transport_spec": transport_spec,
            "plane": plane,
            "log_path": log_path,
        })
        return _PopenHandle(proc, pipe)


_LAUNCHERS = {
    "local": LocalProcessLauncher,
    "subprocess": SubprocessLauncher,
}


def resolve_launcher(launcher: Union[str, Any]) -> Any:
    """``"local"`` / ``"subprocess"`` / an instance with ``.launch(...)``."""
    if isinstance(launcher, str):
        try:
            return _LAUNCHERS[launcher]()
        except KeyError:
            raise ValueError(
                f"unknown launcher {launcher!r} (have: {sorted(_LAUNCHERS)})"
            ) from None
    if not hasattr(launcher, "launch"):
        raise TypeError(f"launcher must expose .launch(...), got {launcher!r}")
    return launcher


def main(argv: Optional[List[str]] = None) -> int:
    """Remote worker entry point: dial the coordinator, run the worker loop."""
    import argparse

    ap = argparse.ArgumentParser(prog="repro.cluster.launcher")
    ap.add_argument("--connect", required=True, help="coordinator host:port")
    ap.add_argument("--token", required=True, help="dial-back auth token")
    args = ap.parse_args(argv)
    host, port = args.connect.rsplit(":", 1)
    sock = socket.create_connection((host, int(port)), timeout=30.0)
    sock.settimeout(None)
    pipe = SocketPipe(sock)
    pipe.send({"token": args.token})
    handshake = pipe.recv()

    from repro.runtime.worker import _worker_main

    _worker_main(
        pipe,
        int(handshake["worker_id"]),
        handshake["transport_spec"],
        handshake["plane"],
        handshake["log_path"],
    )
    return 0


if __name__ == "__main__":  # pragma: no cover - exercised via subprocess
    sys.exit(main())
