"""Worker supervision: heartbeat-driven crash/hang detection + recovery.

The :class:`WorkerSupervisor` arms the multiproc backend's self-healing
machinery and watches the pool from a background thread:

  * **Crash while stepping** — the step RPC fails fast (pipe EOF), the
    backend's ``_step_recover`` hook respawns the worker and the failed
    wave items are re-queued in the dispatch loop; the supervisor merely
    observes the event stream. This is the *fast path*: detection latency
    is one failed RPC, not a heartbeat interval.
  * **Crash while idle** — the heartbeat thread notices the process is
    gone (``is_alive``) and triggers the same recovery, so the next step
    never sees the corpse.
  * **Hang** — ``rpc_timeout`` bounds every reply; an exceeded bound is
    treated as fatal to that incarnation (the pipe is out of sync either
    way) and recovery respawns it. :meth:`check` additionally probes idle
    workers with a bounded ``ping``.

Recovery redeploys segments from the freshest snapshot available
(``snapshot_states``), in one of two modes:

  * **spill** (default for same-host launchers) — each worker pickles
    the post-step states of every segment it owns into one combined
    worker-local file (tmpfs when available), written once per step
    batch *before* the step reply, each entry tagged with a
    completed-step counter. Ephemeral state leaves (keys every step
    overwrites wholesale, e.g. a sink's retained last batch — see
    ``repro.ops.costs.ephemeral_state_keys``) are excluded and re-init
    from the operator template on recovery, so the payload stays a few
    hundred bytes per segment regardless of batch size. No wire traffic,
    no base64: steady-state overhead is one small file write per worker
    per wave, off the coordinator's path. On recovery the counter
    disambiguates a death before the write (state is pre-step: the
    re-dispatch re-steps it, re-publishing idempotently) from one after
    it (the step completed and published: the re-dispatch is skipped) —
    exactly-once either way.
  * **wire** — encoded post-step states piggyback on every step reply,
    committed atomically with it; the only option when workers share no
    filesystem with the coordinator (ssh-shaped launchers). Costs one
    state encode + pipe transfer per step.

Both modes reproduce the uninterrupted trajectory exactly (the
conformance bar in ``tests/test_cluster.py``).
"""
from __future__ import annotations

import threading
from typing import Any, Dict, List, Optional

from .events import HEARTBEAT_MISSED


class WorkerSupervisor:
    """Supervise a :class:`~repro.runtime.worker.MultiprocBackend` pool.

    ``heartbeat_interval`` paces the liveness sweep; ``rpc_timeout``
    (optional) bounds every worker RPC so hangs surface as recoverable
    failures instead of blocking forever; ``snapshot_states`` arms the
    recovery state source, refreshed every ``snapshot_every`` steps (wire
    mode only — spill files are always per-step). ``snapshot_mode`` is
    ``"auto"`` (spill when the launcher's workers share this host's
    filesystem, wire otherwise), ``"spill"`` or ``"wire"``. ``on_event``
    is a convenience alias for the backend's ``on_worker_event`` hook.
    """

    def __init__(
        self,
        backend: Any,
        heartbeat_interval: float = 0.5,
        rpc_timeout: Optional[float] = None,
        snapshot_states: bool = True,
        snapshot_every: int = 1,
        snapshot_mode: str = "auto",
        on_event: Optional[Any] = None,
    ):
        if not hasattr(backend, "recover_worker"):
            raise ValueError(
                "supervision requires a worker-pool backend "
                f"(backend={getattr(backend, 'name', backend)!r} has no "
                "recover_worker); use backend='multiproc'"
            )
        if snapshot_mode not in ("auto", "spill", "wire"):
            raise ValueError(
                f"snapshot_mode must be auto|spill|wire, got {snapshot_mode!r}"
            )
        if snapshot_mode == "auto":
            snapshot_mode = (
                "spill"
                if getattr(backend.launcher, "supports_spill", False)
                else "wire"
            )
        self.backend = backend
        self.heartbeat_interval = heartbeat_interval
        backend.self_heal = True
        backend.snapshot_mode = snapshot_mode if snapshot_states else "wire"
        backend.shadow_states = snapshot_states and snapshot_mode == "wire"
        backend.snapshot_every = max(int(snapshot_every), 1)
        if rpc_timeout is not None:
            backend.rpc_timeout = rpc_timeout
        if on_event is not None:
            backend.on_worker_event = on_event
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -- lifecycle --------------------------------------------------------------
    def start(self) -> "WorkerSupervisor":
        if self._thread is None or not self._thread.is_alive():
            self._stop.clear()
            self._thread = threading.Thread(
                target=self._run, name="repro-supervisor", daemon=True
            )
            self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=self.heartbeat_interval * 4 + 1.0)
            self._thread = None

    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    # -- heartbeats -------------------------------------------------------------
    def _run(self) -> None:
        while not self._stop.wait(self.heartbeat_interval):
            try:
                self._sweep(ping=False)
            except Exception:  # pragma: no cover - sweep must never die
                pass

    def _sweep(self, ping: bool) -> List[int]:
        """One liveness pass; returns the workers recovered."""
        be = self.backend
        if not be._spawned:
            return []
        recovered: List[int] = []
        for i in range(be.n_workers):
            if i >= len(be._procs):  # mid-resize snapshot; next sweep catches up
                break
            gen = be._gen[i]
            dead = not be.worker_alive(i)
            if not dead and ping:
                dead = not be.ping_worker(i)
            if dead and be._gen[i] == gen:
                be._emit_worker_event(HEARTBEAT_MISSED, worker=i,
                                      detail=f"gen={gen}")
                # fetched per-event (get-or-create is idempotent) so the
                # counter survives a configure_obs registry swap
                be.metrics.counter(
                    "repro_supervisor_recoveries_total",
                    "workers recovered by the supervisor liveness sweep",
                ).inc(source="ping" if ping else "heartbeat")
                with be.tracer.span("supervisor_recover", "control",
                                    worker=i, gen=gen):
                    be.recover_worker(i, expect_gen=gen)
                recovered.append(i)
        return recovered

    def check(self) -> List[int]:
        """Synchronous deep health check: ``is_alive`` plus a bounded ping
        per worker. Recovers whatever it finds dead; returns their ids."""
        return self._sweep(ping=True)

    # -- reporting --------------------------------------------------------------
    @property
    def recoveries(self) -> List[Dict[str, Any]]:
        return list(self.backend.respawns)

    def health(self) -> Dict[str, Any]:
        health = dict(self.backend.worker_health() or {})
        health["heartbeat_interval"] = self.heartbeat_interval
        health["heartbeat_running"] = self.running
        return health

    def __enter__(self) -> "WorkerSupervisor":
        return self.start()

    def __exit__(self, *exc: Any) -> None:
        self.stop()
