"""Synthetic IoT sensor streams matching the paper's three sources
(Smart Power Grid, Urban Sensing, NY City Taxi) — §5.2: constant input
rate, event sizes 4–380 bytes, seeded deterministic generators.

Used by the DSPS data plane (repro.runtime) as the raw-stream sources the
merged dataflows share, and by the reuse-serving example as request
feature streams.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict

import numpy as np

SENSOR_TYPES = ("smart_grid", "urban_sensing", "taxi")

_CHANNELS = {"smart_grid": 3, "urban_sensing": 6, "taxi": 8}
_PERIOD = {"smart_grid": 96, "urban_sensing": 288, "taxi": 48}


@dataclass
class SensorStream:
    kind: str
    rate: int = 10  # events/sec (paper's constant input rate)
    seed: int = 0
    _t: int = field(default=0, init=False)

    def __post_init__(self):
        assert self.kind in SENSOR_TYPES, self.kind
        self._rng = np.random.default_rng(self.seed + hash(self.kind) % 2**31)

    @property
    def channels(self) -> int:
        return _CHANNELS[self.kind]

    def next_batch(self, n: int) -> np.ndarray:
        """(n, channels) float32 events: diurnal cycle + AR(1) noise + spikes."""
        c = self.channels
        t = self._t + np.arange(n)[:, None]
        self._t += n
        period = _PERIOD[self.kind]
        diurnal = np.sin(2 * np.pi * t / period + np.arange(c)[None, :])
        noise = self._rng.standard_normal((n, c)).astype(np.float32)
        spikes = (self._rng.random((n, c)) < 0.01) * self._rng.standard_normal((n, c)) * 8
        return (10 * diurnal + noise + spikes).astype(np.float32)
