"""Deterministic, shardable token pipeline.

A *stateless* index→batch mapping (hash-based synthetic corpus with
Zipf-ish marginals and local structure): batch ``i`` is a pure function
of ``(seed, i)``, so
  * restore-from-checkpoint resumes the stream exactly (store only the
    step counter — the paper-grade journal/replay property),
  * every data-parallel host computes only its shard: ``host_id/num_hosts``
    slice the batch dim with no coordination.

Real deployments swap ``_synthesize`` for a tokenized shard reader; the
index discipline (below) is the part that matters at 1000 nodes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


def _phash(*ints: int) -> np.uint64:
    with np.errstate(over="ignore"):  # uint64 wraparound is the point
        h = np.uint64(0x9E3779B97F4A7C15)
        for v in ints:
            h ^= np.uint64(v) + np.uint64(0x9E3779B97F4A7C15) + (h << np.uint64(6)) + (h >> np.uint64(2))
            h *= np.uint64(0xBF58476D1CE4E5B9)
    return h


@dataclass
class TokenStream:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_id: int = 0
    num_hosts: int = 1

    def __post_init__(self):
        assert self.global_batch % self.num_hosts == 0
        self.local_batch = self.global_batch // self.num_hosts

    def batch(self, index: int) -> Dict[str, np.ndarray]:
        """Local shard of global batch ``index`` → {tokens, labels}."""
        b = self.local_batch
        out = np.empty((b, self.seq_len + 1), np.int32)
        for r in range(b):
            gr = self.host_id * b + r
            out[r] = self._synthesize(index, gr)
        return {"tokens": out[:, :-1], "labels": out[:, 1:].copy()}

    def _synthesize(self, index: int, row: int) -> np.ndarray:
        rng = np.random.default_rng(int(_phash(self.seed, index, row)))
        n = self.seq_len + 1
        # Zipf-ish unigrams with short repeated motifs (gives a learnable
        # next-token structure so loss visibly decreases)
        base = rng.zipf(1.3, size=n).astype(np.int64)
        toks = (base - 1) % self.vocab_size
        n_motif = max(n // 64, 1)
        starts = rng.integers(0, max(n - 16, 1), size=n_motif)
        motif = rng.integers(0, self.vocab_size, size=8)
        for s in starts:
            toks[s : s + 8] = motif[: max(0, min(8, n - s))]
        return toks.astype(np.int32)


def make_lm_batch_iter(stream: TokenStream, start_index: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    i = start_index
    while True:
        yield stream.batch(i)
        i += 1
