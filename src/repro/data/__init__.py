"""Data pipeline: deterministic token streams for LM training and the
synthetic IoT sensor sources the paper's dataflows consume."""
from .tokens import TokenStream, make_lm_batch_iter
from .sensors import SensorStream, SENSOR_TYPES

__all__ = ["TokenStream", "make_lm_batch_iter", "SensorStream", "SENSOR_TYPES"]
