"""Task cost model — the single source of truth for ``cost_weight``.

``cost_weight`` is the relative per-event CPU cost used by the resource
accounting that reproduces the paper's Fig. 3 (cumulative cores). The jit
operator factories (:mod:`repro.ops.riot`, :mod:`repro.ops.sources`,
:mod:`repro.ops.sinks`, :mod:`repro.serve.model_ops`) read their weights
from here, and :class:`repro.runtime.dryrun.DryRunBackend` evaluates the
same weights **without** instantiating any JAX operator — which is what
makes its cost trajectories contract-identical to the jit backends while
never importing JAX.

This module must therefore stay free of JAX imports.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Any, Dict, Mapping, Sequence, Tuple

SOURCE_COST = 0.3
SINK_COST = 0.3

# RIoTBench task families (parse < filter < window stats < predict) —
# relative weights mirroring the costs reported per category.
RIOT_COSTS: Dict[str, float] = {
    # ETL
    "senml_parse": 3.0,
    "csv_parse": 2.0,
    "range_filter": 0.5,
    "bloom_filter": 1.5,
    "interpolate": 1.5,
    "join": 0.4,
    "annotate": 0.3,
    # STATS
    "kalman": 2.0,
    "win": 1.8,
    "avg": 1.0,
    "moment2": 1.4,
    "distinct_count": 1.1,
    "rmsnorm": 1.2,
    # PREDICT
    "linreg": 1.6,
    "dtree": 1.3,
    "sliding_linreg": 2.2,
    "error_estimate": 0.4,
}

# LM-pipeline stages (multi-tenant reuse serving).
LM_EMBED_COST = 0.2
LM_STAGE_COST_PER_BLOCK = 1.0
LM_HEAD_COST = 0.4

# OPMW synthetic π task: cost scales with the iteration count.
PI_COST_PER_ITER = 0.02
PI_DEFAULT_ITERS = 100


def parse_config(config: Any) -> Dict[str, Any]:
    """Inverse of :func:`repro.core.graph.canonical_config` for dict configs."""
    if isinstance(config, Mapping):
        return dict(config)
    if isinstance(config, str):
        if config in ("SOURCE", "SINK"):
            return {}
        try:
            obj = json.loads(config)
            return obj if isinstance(obj, dict) else {"value": obj}
        except (json.JSONDecodeError, ValueError):
            return {"value": config}
    return {}


def pi_cost(cfg: Mapping[str, Any]) -> float:
    return PI_COST_PER_ITER * int(cfg.get("iters", PI_DEFAULT_ITERS))


def lm_stage_cost(cfg: Mapping[str, Any]) -> float:
    lo, hi = (int(v) for v in str(cfg.get("layers", "0-0")).split("-"))
    return LM_STAGE_COST_PER_BLOCK * (hi - lo + 1)


def cost_weight_for(
    type_name: str,
    config: Any = None,
    *,
    is_source: bool = False,
    is_sink: bool = False,
) -> float:
    """cost_weight of the operator ⟨type, config⟩ — without building it.

    Must stay in lockstep with :func:`repro.ops.operator_for_task`: the
    conformance tests assert that dry-run and jit backends report identical
    cost trajectories.
    """
    if is_source:
        return SOURCE_COST
    if is_sink:
        return SINK_COST
    if type_name in RIOT_COSTS:
        return RIOT_COSTS[type_name]
    cfg = parse_config(config)
    if type_name == "lm_embed":
        return LM_EMBED_COST
    if type_name == "lm_stage":
        return lm_stage_cost(cfg)
    if type_name == "lm_head":
        return LM_HEAD_COST
    # unknown task types fall back to the OPMW iterative-π logic (§5.1)
    return pi_cost(cfg)


def cost_weight_for_task(task: Any) -> float:
    """cost_weight of a concrete :class:`repro.core.graph.Task`."""
    return cost_weight_for(
        task.type, task.config, is_source=task.is_source, is_sink=task.is_sink
    )


# State leaves that a task's ``apply()`` overwrites wholesale every step
# without ever reading — scratch outputs like a sink's retained ``last``
# batch. Per-step recovery spills skip them (they self-heal on the first
# post-recovery step, and nothing downstream observes them before that);
# checkpoints, ``states`` RPCs and wire snapshots stay byte-exact. Lives
# here, not on :class:`~repro.ops.base.Operator`, because the multiproc
# coordinator and dry workers consult it without importing JAX.
_EPHEMERAL_SINK_KEYS = ("last",)


def ephemeral_state_keys(task: Any) -> tuple:
    """Spill-excluded state keys of a :class:`repro.core.graph.Task`."""
    return _EPHEMERAL_SINK_KEYS if task.is_sink else ()


# -- dry-run latency calibration ------------------------------------------------
#
# cost_weight is a *relative* per-event CPU cost; it says nothing about
# milliseconds. The LatencyModel closes that gap: fit per-task-type
# ms-per-work-unit coefficients (work unit = cost_weight × batch) from
# segment wall-times a jit backend actually measured
# (ExecutionBackend.latency_samples), and the DryRunBackend then reports
# realistic segment_ms — which is what makes its concurrent-mode makespan
# model (per-wave max) a meaningful wall-clock predictor.


@dataclass(frozen=True)
class LatencyModel:
    """Per-task-type wall-time model: ``ms ≈ Σ_type coef[type] · units``."""

    ms_per_unit: Dict[str, float]
    default_ms_per_unit: float = 0.0  # fallback for task types never observed

    def segment_ms(self, units: Mapping[str, float]) -> float:
        """Predicted step wall-time of a segment from its per-type work units."""
        return sum(
            self.ms_per_unit.get(t, self.default_ms_per_unit) * u
            for t, u in units.items()
        )


def fit_latency_model(
    samples: Sequence[Tuple[Mapping[str, float], float]],
) -> LatencyModel:
    """Least-squares fit of per-task-type latency coefficients.

    ``samples`` are ⟨per-type work units, measured segment ms⟩ pairs (the
    output of :meth:`ExecutionBackend.latency_samples`). Solves the
    minimum-norm least-squares system, clips negative coefficients to 0
    (a type can't speed a segment up), and keeps the global mean
    ms-per-unit as the fallback for types never observed.
    """
    import numpy as np

    samples = [(dict(u), float(ms)) for u, ms in samples if u]
    if not samples:
        return LatencyModel({})
    types = sorted({t for units, _ in samples for t in units})
    a = np.array([[units.get(t, 0.0) for t in types] for units, _ in samples])
    y = np.array([ms for _, ms in samples])
    coef, *_ = np.linalg.lstsq(a, y, rcond=None)
    coef = np.clip(coef, 0.0, None)
    total_units = float(a.sum())
    default = float(y.sum() / total_units) if total_units > 0 else 0.0
    return LatencyModel(dict(zip(types, coef.tolist())), default_ms_per_unit=default)
