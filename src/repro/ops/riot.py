"""Real IoT task logic in JAX — the RIoTBench task families (paper §5.1).

The RIoT workload composes ~19 distinct task types (parse/filter/quality,
windowed statistics, predictive analytics) into 21 IoT dataflows. Each task
here is real numerics over event batches of shape ``(B, EVENT_WIDTH)``:

  channel 0    timestamp
  channels 1-5 observation values (5 sensor channels)
  channel 6    validity flag (1.0 = valid)
  channel 7    event id / hash key

Cost weights are relative per-event CPU costs used by the Fig. 3 resource
accounting; they were chosen to mirror the relative costs reported for
RIoTBench task categories (parse < filter < window stats < predict).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from .base import EVENT_WIDTH, Operator, register, register_fallback, stateless
from .costs import RIOT_COSTS, parse_config, pi_cost

VAL = slice(1, 6)  # observation channels
FLAG = 6
KEY = 7

# Straight-line runs of these types can be collapsed onto one multi-op
# pallas kernel when a fused segment is compiled (see
# runtime/segment.py:_peephole_fused_kernels): FUSABLE_ELEMENTWISE types
# may appear anywhere in the run, FUSED_TAILS terminate it.
FUSABLE_ELEMENTWISE = ("senml_parse",)
FUSED_TAILS = ("rmsnorm", "senml_parse")


def make_fused_operator(tasks, batch: int) -> Any:
    """One operator computing a ``senml_parse* → (rmsnorm|senml_parse)`` run.

    ``tasks`` is the run in head→tail dataflow order. The returned
    operator replaces the *tail* task inside a fused segment and consumes
    the head's input; it dispatches through the multi-op pallas kernels
    (:func:`repro.kernels.ops.affine_rmsnorm` / ``map_chain``) with the
    stages replayed sequentially, so outputs are bit-identical to the
    unfused op-by-op execution on every backend. State structure and cost
    weight are the tail's (both tails are stateless), keeping checkpoint
    layout and Fig. 3 cost accounting unchanged. Returns ``None`` for
    runs this factory does not understand.
    """
    if len(tasks) < 2:
        return None
    *heads, tail = tasks
    if any(t.type not in FUSABLE_ELEMENTWISE for t in heads):
        return None
    if tail.type not in FUSED_TAILS:
        return None

    def _stage(cfg: Dict[str, Any]):
        return (float(cfg.get("scale", 1.0)), float(cfg.get("offset", 0.0)))

    stages = tuple(_stage(parse_config(t.config)) for t in heads)
    tail_cfg = parse_config(tail.config)

    if tail.type == "rmsnorm":
        eps = float(tail_cfg.get("eps", 1e-6))
        gain = float(tail_cfg.get("gain", 1.0))

        def fn(x: jnp.ndarray) -> jnp.ndarray:
            from repro.kernels import ops as kernel_ops

            scale = jnp.full((5,), gain, dtype=x.dtype)
            vals = kernel_ops.affine_rmsnorm(x[:, VAL], scale, stages=stages, eps=eps)
            return x.at[:, VAL].set(vals)

    else:  # senml_parse tail — its own affine is just the last stage
        all_stages = stages + (_stage(tail_cfg),)

        def fn(x: jnp.ndarray) -> jnp.ndarray:
            from repro.kernels import ops as kernel_ops

            vals = kernel_ops.map_chain(x[:, VAL], stages=all_stages)
            return x.at[:, VAL].set(vals)

    return stateless(tail.type, fn, cost=RIOT_COSTS[tail.type])


def _hash_channel(x: jnp.ndarray, salt: int) -> jnp.ndarray:
    """Cheap integer hash of the id channel (splitmix-style)."""
    z = (x[:, KEY] * 2654435761.0 + float(salt)).astype(jnp.int32)
    z = jnp.bitwise_xor(z, z >> 16) * jnp.int32(0x45D9F3B)
    z = jnp.bitwise_xor(z, z >> 16)
    return z


# -- ETL family ---------------------------------------------------------------

@register("senml_parse")
def senml_parse(cfg: Dict[str, Any]) -> Operator:
    """Decode: per-channel affine normalization (scale/offset from config)."""
    scale = float(cfg.get("scale", 1.0))
    offset = float(cfg.get("offset", 0.0))

    def fn(x: jnp.ndarray) -> jnp.ndarray:
        vals = x[:, VAL] * scale + offset
        return x.at[:, VAL].set(vals)

    return stateless("senml_parse", fn, cost=RIOT_COSTS["senml_parse"])


@register("csv_parse")
def csv_parse(cfg: Dict[str, Any]) -> Operator:
    """Field re-ordering + cast — a fixed channel permutation."""
    shift = int(cfg.get("shift", 1)) % 5

    def fn(x: jnp.ndarray) -> jnp.ndarray:
        vals = jnp.roll(x[:, VAL], shift=shift, axis=1)
        return x.at[:, VAL].set(vals)

    return stateless("csv_parse", fn, cost=RIOT_COSTS["csv_parse"])


@register("range_filter")
def range_filter(cfg: Dict[str, Any]) -> Operator:
    """Quality check: flag events whose channel-1 value is out of [lo, hi]."""
    lo = float(cfg.get("lo", -1e3))
    hi = float(cfg.get("hi", 1e3))

    def fn(x: jnp.ndarray) -> jnp.ndarray:
        ok = (x[:, 1] >= lo) & (x[:, 1] <= hi)
        return x.at[:, FLAG].set(x[:, FLAG] * ok.astype(x.dtype))

    return stateless("range_filter", fn, cost=RIOT_COSTS["range_filter"])


@register("bloom_filter")
def bloom_filter(cfg: Dict[str, Any]) -> Operator:
    """Membership filter with a real bitset state (m buckets, k salts)."""
    m = int(cfg.get("m", 1024))
    salts = tuple(range(int(cfg.get("k", 3))))

    def init_state(batch: int):
        return jnp.zeros((m,), dtype=jnp.int32)

    def apply(state, x):
        seen = jnp.ones((x.shape[0],), dtype=jnp.bool_)
        new = state
        for s in salts:
            idx = jnp.abs(_hash_channel(x, s)) % m
            seen = seen & (state[idx] > 0)
            new = new.at[idx].set(1)
        # mark duplicate events invalid (flag *= not-seen)
        y = x.at[:, FLAG].set(x[:, FLAG] * (~seen).astype(x.dtype))
        return new, y

    return Operator("bloom_filter", init_state, apply, cost_weight=RIOT_COSTS["bloom_filter"])


@register("interpolate")
def interpolate(cfg: Dict[str, Any]) -> Operator:
    """Replace invalid observations with the last valid value (per channel)."""

    def init_state(batch: int):
        return jnp.zeros((5,), dtype=jnp.float32)

    def apply(state, x):
        def step(carry, row):
            valid = row[FLAG] > 0.5
            vals = jnp.where(valid, row[VAL], carry)
            return vals, row.at[VAL].set(vals).at[FLAG].set(1.0)

        new_state, y = jax.lax.scan(step, state, x)
        return new_state, y

    return Operator("interpolate", init_state, apply, cost_weight=RIOT_COSTS["interpolate"])


@register("join")
def join(cfg: Dict[str, Any]) -> Operator:
    """Interleave-join: pass events through, stamping a join counter."""

    def init_state(batch: int):
        return jnp.zeros((), dtype=jnp.int32)

    def apply(state, x):
        return state + 1, x.at[:, 0].add(0.0)  # timestamp untouched; count advances

    return Operator("join", init_state, apply, cost_weight=RIOT_COSTS["join"])


@register("annotate")
def annotate(cfg: Dict[str, Any]) -> Operator:
    """Metadata annotation: add a constant tag into channel 5."""
    tag = float(cfg.get("tag", 1.0))

    def fn(x: jnp.ndarray) -> jnp.ndarray:
        return x.at[:, 5].set(tag)

    return stateless("annotate", fn, cost=RIOT_COSTS["annotate"])


# -- STATS family --------------------------------------------------------------

@register("kalman")
def kalman(cfg: Dict[str, Any]) -> Operator:
    """Scalar Kalman filter per observation channel (real recurrence)."""
    q = float(cfg.get("q", 0.1))  # process noise
    r = float(cfg.get("r", 1.0))  # measurement noise

    def init_state(batch: int):
        return {"x": jnp.zeros((5,)), "p": jnp.ones((5,))}

    def apply(state, x):
        def step(carry, row):
            xe, p = carry
            p_pred = p + q
            k = p_pred / (p_pred + r)
            xe2 = xe + k * (row[VAL] - xe)
            p2 = (1.0 - k) * p_pred
            return (xe2, p2), row.at[VAL].set(xe2)

        (xe, p), y = jax.lax.scan(step, (state["x"], state["p"]), x)
        return {"x": xe, "p": p}, y

    return Operator("kalman", init_state, apply, cost_weight=RIOT_COSTS["kalman"])


@register("win")
def sliding_window(cfg: Dict[str, Any]) -> Operator:
    """Sliding window: ring buffer of the last w batch-means, emits window mean."""
    w = int(cfg.get("w", 10))

    def init_state(batch: int):
        return {"buf": jnp.zeros((w, 5)), "n": jnp.zeros((), jnp.int32)}

    def apply(state, x):
        mean = x[:, VAL].mean(axis=0)
        idx = state["n"] % w
        buf = state["buf"].at[idx].set(mean)
        n = state["n"] + 1
        denom = jnp.minimum(n, w).astype(jnp.float32)
        agg = buf.sum(axis=0) / denom
        # values re-centered around the window aggregate
        return {"buf": buf, "n": n}, x.at[:, VAL].set(x[:, VAL] - agg)

    return Operator("win", init_state, apply, cost_weight=RIOT_COSTS["win"])


@register("avg")
def block_average(cfg: Dict[str, Any]) -> Operator:
    """Running (cumulative) average — Welford mean per channel."""

    def init_state(batch: int):
        return {"mean": jnp.zeros((5,)), "n": jnp.zeros((), jnp.float32)}

    def apply(state, x):
        bmean = x[:, VAL].mean(axis=0)
        n = state["n"] + 1.0
        mean = state["mean"] + (bmean - state["mean"]) / n
        return {"mean": mean, "n": n}, x.at[:, VAL].set(x[:, VAL] - mean)

    return Operator("avg", init_state, apply, cost_weight=RIOT_COSTS["avg"])


@register("moment2")
def second_order_moment(cfg: Dict[str, Any]) -> Operator:
    """Running variance (Welford) — stamps normalized values."""

    def init_state(batch: int):
        return {"mean": jnp.zeros((5,)), "m2": jnp.zeros((5,)), "n": jnp.zeros(())}

    def apply(state, x):
        bmean = x[:, VAL].mean(axis=0)
        n = state["n"] + 1.0
        delta = bmean - state["mean"]
        mean = state["mean"] + delta / n
        m2 = state["m2"] + delta * (bmean - mean)
        var = m2 / jnp.maximum(n - 1.0, 1.0)
        y = x.at[:, VAL].set((x[:, VAL] - mean) * jax.lax.rsqrt(var + 1e-6))
        return {"mean": mean, "m2": m2, "n": n}, y

    return Operator("moment2", init_state, apply, cost_weight=RIOT_COSTS["moment2"])


@register("rmsnorm")
def rmsnorm_op(cfg: Dict[str, Any]) -> Operator:
    """RMS-normalize the observation channels via the kernel library.

    Dispatches through :func:`repro.kernels.ops.rmsnorm` — the Pallas
    kernel on TPU, the reference einsum elsewhere — so fusion-compiled
    segment chains exercise real accelerator kernels where they exist.
    """
    eps = float(cfg.get("eps", 1e-6))
    gain = float(cfg.get("gain", 1.0))

    def fn(x: jnp.ndarray) -> jnp.ndarray:
        from repro.kernels import ops as kernel_ops

        scale = jnp.full((5,), gain, dtype=x.dtype)
        vals = kernel_ops.rmsnorm(x[:, VAL], scale, eps=eps)
        return x.at[:, VAL].set(vals)

    return stateless("rmsnorm", fn, cost=RIOT_COSTS["rmsnorm"])


@register("distinct_count")
def distinct_count(cfg: Dict[str, Any]) -> Operator:
    """Approximate distinct count (linear-counting bitset)."""
    m = int(cfg.get("m", 512))

    def init_state(batch: int):
        return jnp.zeros((m,), dtype=jnp.int32)

    def apply(state, x):
        idx = jnp.abs(_hash_channel(x, 7)) % m
        bits = state.at[idx].set(1)
        zeros = (m - bits.sum()).astype(jnp.float32)
        est = -float(m) * jnp.log(jnp.maximum(zeros, 1.0) / float(m))
        return bits, x.at[:, 5].set(est)

    return Operator("distinct_count", init_state, apply, cost_weight=RIOT_COSTS["distinct_count"])


# -- PREDICT family --------------------------------------------------------------

@register("linreg")
def multivar_linreg(cfg: Dict[str, Any]) -> Operator:
    """Multi-variate linear regression predict: ŷ = w·x + b (fixed weights)."""
    seed = int(cfg.get("seed", 0))
    w = jax.random.normal(jax.random.PRNGKey(seed), (5,)) * 0.3

    def fn(x: jnp.ndarray) -> jnp.ndarray:
        pred = x[:, VAL] @ w
        return x.at[:, 5].set(pred)

    return stateless("linreg", fn, cost=RIOT_COSTS["linreg"])


@register("dtree")
def decision_tree(cfg: Dict[str, Any]) -> Operator:
    """Fixed-depth decision-tree classifier over the observation channels."""
    t1 = float(cfg.get("t1", 0.0))
    t2 = float(cfg.get("t2", 0.5))
    t3 = float(cfg.get("t3", -0.5))

    def fn(x: jnp.ndarray) -> jnp.ndarray:
        c = jnp.where(
            x[:, 1] > t1,
            jnp.where(x[:, 2] > t2, 2.0, 1.0),
            jnp.where(x[:, 3] > t3, 0.0, -1.0),
        )
        return x.at[:, 5].set(c)

    return stateless("dtree", fn, cost=RIOT_COSTS["dtree"])


@register("sliding_linreg")
def sliding_linreg(cfg: Dict[str, Any]) -> Operator:
    """OLS trend over a ring buffer of batch means (2x2 normal equations)."""
    w = int(cfg.get("w", 16))

    def init_state(batch: int):
        return {"buf": jnp.zeros((w,)), "n": jnp.zeros((), jnp.int32)}

    def apply(state, x):
        mean = x[:, 1].mean()
        idx = state["n"] % w
        buf = state["buf"].at[idx].set(mean)
        n = state["n"] + 1
        t = jnp.arange(w, dtype=jnp.float32)
        mask = (t < jnp.minimum(n, w)).astype(jnp.float32)
        cnt = mask.sum()
        tm = (t * mask).sum() / cnt
        ym = (buf * mask).sum() / cnt
        cov = ((t - tm) * (buf - ym) * mask).sum()
        var = ((t - tm) ** 2 * mask).sum()
        slope = cov / jnp.maximum(var, 1e-6)
        return {"buf": buf, "n": n}, x.at[:, 5].set(slope)

    return Operator("sliding_linreg", init_state, apply, cost_weight=RIOT_COSTS["sliding_linreg"])


@register("error_estimate")
def error_estimate(cfg: Dict[str, Any]) -> Operator:
    """|prediction − observation| into channel 4."""

    def fn(x: jnp.ndarray) -> jnp.ndarray:
        return x.at[:, 4].set(jnp.abs(x[:, 5] - x[:, 1]))

    return stateless("error_estimate", fn, cost=RIOT_COSTS["error_estimate"])


# -- OPMW synthetic π task (paper §5.1) -----------------------------------------

@register("pi")
def pi_task(cfg: Dict[str, Any]) -> Operator:
    return _pi_operator(cfg, "pi")


@register_fallback
def _fallback(cfg: Dict[str, Any]) -> Operator:
    """Unknown task types (the OPMW workload) run the iterative π logic —
    exactly the paper's substitution of OPMW task internals."""
    return _pi_operator(cfg, cfg.get("_type", "pi"))


def _pi_operator(cfg: Dict[str, Any], type_name: str) -> Operator:
    iters = int(cfg.get("iters", 100))

    def fn(x: jnp.ndarray) -> jnp.ndarray:
        def body(i, acc):
            k = i.astype(jnp.float32)
            return acc + jnp.where(i % 2 == 0, 1.0, -1.0) * 4.0 / (2.0 * k + 1.0)

        pi_est = jax.lax.fori_loop(0, iters, body, jnp.zeros(()))
        return x.at[:, 5].set(pi_est)

    # π cost scales with the iteration count (CPU-intensive per event).
    return stateless(type_name, fn, cost=pi_cost(cfg))
