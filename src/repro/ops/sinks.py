"""Sink operators — accumulate an output digest.

A sink's state carries ``(count, checksum, last)``: the number of batches
consumed, a running float checksum of every payload, and the last batch.
The checksum is the *observable output stream identity*: the paper requires
that running-DAG outputs be indistinguishable from standalone execution, so
the test suite compares sink checksums between Default and Reuse runs.
"""
from __future__ import annotations

from typing import Any, Dict

import jax.numpy as jnp

from .base import EVENT_WIDTH, Operator
from .costs import SINK_COST


def make_sink(type_name: str) -> Operator:
    def init_state(batch: int):
        return {
            "count": jnp.zeros((), jnp.int32),
            "checksum": jnp.zeros((), jnp.float32),
            "last": jnp.zeros((batch, EVENT_WIDTH), jnp.float32),
        }

    def apply(state, x):
        return (
            {
                "count": state["count"] + 1,
                # weighted fold so the checksum is order-sensitive
                "checksum": state["checksum"] * 0.5 + jnp.sum(x, dtype=jnp.float32),
                "last": x,
            },
            None,
        )

    return Operator(
        type=type_name, init_state=init_state, apply=apply, cost_weight=SINK_COST, is_sink=True
    )
