"""Task-type registry: ⟨type, config⟩ → executable JAX operator.

Real RIoT-style IoT task logic (:mod:`repro.ops.riot`), deterministic
synthetic sources (:mod:`repro.ops.sources`), digest sinks
(:mod:`repro.ops.sinks`), and the OPMW π fallback. Model-block operators
(embed / layer-group / head for multi-tenant LM serving) are registered by
:mod:`repro.serve.model_ops` when imported.

The package init is lazy (PEP 562): the JAX operator modules only load on
first attribute access, so the jax-free cost model (:mod:`repro.ops.costs`,
used by the dry-run backend) can be imported without pulling in JAX.
"""
from __future__ import annotations

import importlib
from typing import TYPE_CHECKING

from .costs import cost_weight_for, cost_weight_for_task, parse_config

_BASE_NAMES = {
    "EVENT_WIDTH",
    "Operator",
    "make_operator",
    "register",
    "register_fallback",
    "registered_types",
    "stateless",
}

if TYPE_CHECKING:  # pragma: no cover - static imports for type checkers
    from .base import (
        EVENT_WIDTH,
        Operator,
        make_operator,
        register,
        register_fallback,
        registered_types,
        stateless,
    )
    from .sinks import make_sink
    from .sources import make_source

__all__ = [
    "EVENT_WIDTH",
    "Operator",
    "cost_weight_for",
    "cost_weight_for_task",
    "make_operator",
    "make_sink",
    "make_source",
    "operator_for_task",
    "parse_config",
    "register",
    "register_fallback",
    "registered_types",
    "stateless",
]


def operator_for_task(task, batch: int = 32):
    """Instantiate the JAX operator for a concrete task (source/sink aware)."""
    from . import riot  # noqa: F401 — populates the registry (imports JAX)
    from .base import make_operator
    from .sinks import make_sink
    from .sources import make_source

    if task.is_source:
        return make_source(task.type, batch=batch)
    if task.is_sink:
        return make_sink(task.type)
    return make_operator(task.type, task.config)


def __getattr__(name: str):
    if name in _BASE_NAMES:
        from . import riot  # noqa: F401 — registry side effects before use
        module = importlib.import_module(f"{__name__}.base")
    elif name == "make_sink":
        module = importlib.import_module(f"{__name__}.sinks")
    elif name == "make_source":
        module = importlib.import_module(f"{__name__}.sources")
    else:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    value = getattr(module, name)
    globals()[name] = value  # cache for subsequent lookups
    return value
