"""Task-type registry: ⟨type, config⟩ → executable JAX operator.

Real RIoT-style IoT task logic (:mod:`repro.ops.riot`), deterministic
synthetic sources (:mod:`repro.ops.sources`), digest sinks
(:mod:`repro.ops.sinks`), and the OPMW π fallback. Model-block operators
(embed / layer-group / head for multi-tenant LM serving) are registered by
:mod:`repro.serve.model_ops` when imported.
"""
from . import riot  # noqa: F401 — populates the registry
from .base import (
    EVENT_WIDTH,
    Operator,
    make_operator,
    parse_config,
    register,
    register_fallback,
    registered_types,
    stateless,
)
from .sinks import make_sink
from .sources import make_source


def operator_for_task(task, batch: int = 32) -> Operator:
    """Instantiate the operator for a concrete task (source/sink aware)."""
    if task.is_source:
        return make_source(task.type, batch=batch)
    if task.is_sink:
        return make_sink(task.type)
    return make_operator(task.type, task.config)


__all__ = [
    "EVENT_WIDTH",
    "Operator",
    "make_operator",
    "make_sink",
    "make_source",
    "operator_for_task",
    "parse_config",
    "register",
    "register_fallback",
    "registered_types",
    "stateless",
]
