"""Operator protocol + task-type registry.

The paper's tasks are user logic ``⟨type, config⟩`` executed once per input
event. On a TPU data plane events are *batched*: every stream carries an
event-batch tensor of shape ``(B, EVENT_WIDTH)`` per step, and a task is a
pure JAX function over one batch, with explicit state (a pytree) — the
analogue of a Storm Bolt's instance fields. Tasks therefore compose into a
single jit-compiled program per segment (see :mod:`repro.runtime.segment`).

Semantics (paper §3.1):
  * *interleave* — a task with multiple input streams is applied once per
    incoming batch, in deterministic (sorted-parent) order;
  * *duplicate* — each consumer of a task's output receives the same batch
    (zero-copy fan-out of one device buffer).

``cost_weight`` is the relative per-event CPU cost used by the resource
accounting that reproduces the paper's Fig. 3 (cumulative cores); it is
calibrated per task family and also cross-checked against measured FLOPs.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp

from .costs import parse_config  # noqa: F401 — canonical home is the jax-free cost model

# Payload width of an event batch: every event is a fixed-width float vector
# (sensor observations: timestamp, value channels, quality flags ...).
EVENT_WIDTH = 8

PyTree = Any
ApplyFn = Callable[[PyTree, jnp.ndarray], Tuple[PyTree, Optional[jnp.ndarray]]]


@dataclass
class Operator:
    """A compiled-composable task implementation.

    ``init_state(batch)`` returns the task's state pytree (fixed shapes);
    ``apply(state, x)`` consumes one event batch and returns
    ``(new_state, output batch | None)``. Sources take ``x=None``; sinks
    return ``None`` output.
    """

    type: str
    init_state: Callable[[int], PyTree]
    apply: ApplyFn
    cost_weight: float = 1.0
    is_source: bool = False
    is_sink: bool = False


OperatorFactory = Callable[[Dict[str, Any]], Operator]

_REGISTRY: Dict[str, OperatorFactory] = {}
_FALLBACK: Optional[OperatorFactory] = None


def register(type_name: str) -> Callable[[OperatorFactory], OperatorFactory]:
    def deco(factory: OperatorFactory) -> OperatorFactory:
        if type_name in _REGISTRY:
            raise ValueError(f"operator type {type_name!r} already registered")
        _REGISTRY[type_name] = factory
        return factory

    return deco


def register_fallback(factory: OperatorFactory) -> OperatorFactory:
    """Factory used for unknown task types (the OPMW workload replaces all
    task logic with an iterative π computation — paper §5.1)."""
    global _FALLBACK
    _FALLBACK = factory
    return factory


def make_operator(type_name: str, config: Any) -> Operator:
    """Instantiate the operator for a concrete task ⟨type, config⟩."""
    cfg = parse_config(config)
    factory = _REGISTRY.get(type_name)
    if factory is None:
        if _FALLBACK is None:
            raise KeyError(f"no operator registered for task type {type_name!r}")
        cfg = dict(cfg, _type=type_name)
        return _FALLBACK(cfg)
    return factory(cfg)


def registered_types() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# -- conveniences for defining ops ------------------------------------------

def stateless(type_name: str, fn: Callable[[jnp.ndarray], jnp.ndarray], cost: float) -> Operator:
    """Operator with no state: y = fn(x)."""

    def init_state(batch: int) -> PyTree:
        return ()

    def apply(state: PyTree, x: jnp.ndarray):
        return state, fn(x)

    return Operator(type=type_name, init_state=init_state, apply=apply, cost_weight=cost)
