"""Source operators — deterministic synthetic sensor streams.

The paper uses 3 IoT source streams (Smart Power Grid, Urban Sensing, NY
Taxi) at a constant 10 events/sec. Here a source's state is a step counter
and its output is a *pure function of (source type, counter)* — so a source
task shared between merged dataflows emits exactly the stream each tenant
would have seen standalone. This determinism is what lets the test suite
assert bit-identical sink outputs between the Default and Reuse runs (the
paper's output-consistency guarantee).
"""
from __future__ import annotations

import hashlib
from typing import Any, Dict

import jax
import jax.numpy as jnp

from .base import EVENT_WIDTH, Operator
from .costs import SOURCE_COST

# Distinct signal profiles per source family: (bias, amplitude, period, noise)
_PROFILES = {
    "urban": (20.0, 5.0, 60.0, 0.8),    # temperature-ish urban sensing
    "meter": (1.2, 0.6, 1440.0, 0.1),   # smart-meter kW draw
    "grid": (50.0, 0.05, 3600.0, 0.02), # grid frequency
    "taxi": (8.0, 6.0, 720.0, 2.0),     # taxi trip metric
}
_DEFAULT_PROFILE = (0.0, 1.0, 100.0, 0.5)


def _seed_for(type_name: str) -> int:
    return int.from_bytes(hashlib.sha256(type_name.encode()).digest()[:4], "little")


def make_source(type_name: str, batch: int = 32) -> Operator:
    """Deterministic stream: sinusoid + seeded per-step noise + event ids."""
    bias, amp, period, noise = _PROFILES.get(type_name.split(":")[0], _DEFAULT_PROFILE)
    seed = _seed_for(type_name)

    def init_state(batch_: int):
        return jnp.zeros((), dtype=jnp.int32)

    def apply(state, x=None):
        step = state
        key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
        t = step.astype(jnp.float32) + jnp.arange(batch, dtype=jnp.float32) / batch
        base = bias + amp * jnp.sin(2.0 * jnp.pi * t / period)
        vals = base[:, None] + noise * jax.random.normal(key, (batch, 5))
        out = jnp.zeros((batch, EVENT_WIDTH), dtype=jnp.float32)
        out = out.at[:, 0].set(t)
        out = out.at[:, 1:6].set(vals)
        out = out.at[:, 6].set(1.0)  # valid
        ids = step * batch + jnp.arange(batch)
        out = out.at[:, 7].set(ids.astype(jnp.float32))
        return state + 1, out

    return Operator(
        type=type_name,
        init_state=init_state,
        apply=apply,
        cost_weight=SOURCE_COST,
        is_source=True,
    )
