"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --smoke \
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Production path (real pod): drop --smoke, point --mesh at the pod, and
the same code jits under the production mesh with the cell shardings.
Fault tolerance: async checkpoint every --ckpt-every steps; on restart
the driver restores the latest checkpoint (resharding onto the current
mesh if its size changed) and resumes the data stream at the exact batch
index — the loop is crash-idempotent.
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.data import TokenStream
from repro.models import forward, init_params
from repro.train import AdamWConfig, make_train_step, train_state_init
from repro.train import checkpoint as ckpt


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get_smoke_config(args.arch) if args.smoke else configs.get_config(args.arch)
    # family chunk constraints (ssd/mlstm need seq % chunk == 0)
    if cfg.ssm:
        assert args.seq % cfg.ssm.chunk == 0
    if cfg.xlstm:
        assert args.seq % cfg.xlstm.chunk == 0

    opt = AdamWConfig(
        peak_lr=args.lr, warmup_steps=args.warmup, total_steps=args.steps,
        mu_dtype="float32", nu_dtype="float32",
    )
    step_fn = jax.jit(make_train_step(cfg, opt, accum=args.accum), donate_argnums=0)
    stream = TokenStream(cfg.vocab_size, args.seq, args.batch, seed=args.seed)

    start_step = 0
    state = None
    if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        target = jax.eval_shape(
            lambda: train_state_init(cfg, opt, jax.random.PRNGKey(args.seed))
        )
        state = ckpt.restore(args.ckpt_dir, target=target)
        state = jax.tree.map(jnp.asarray, state)
        start_step = int(state["step"])
        print(f"restored checkpoint at step {start_step}")
    if state is None:
        state = train_state_init(cfg, opt, jax.random.PRNGKey(args.seed))

    total, active = cfg.param_count()
    print(f"{cfg.name}: {total/1e6:.1f}M params ({active/1e6:.1f}M active)")
    saver = ckpt.AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None

    def make_batch(i):
        b = stream.batch(i)
        out = {"tokens": jnp.asarray(b["tokens"]), "labels": jnp.asarray(b["labels"])}
        if cfg.family == "vlm":
            out["memory"] = _stub_memory(cfg, args.batch, cfg.num_image_tokens, i)
        elif cfg.family == "audio":
            out["memory"] = _stub_memory(cfg, args.batch, cfg.encoder_seq, i)
        return out

    t0 = time.time()
    first_loss = last_loss = None
    for i in range(start_step, args.steps):
        state, metrics = step_fn(state, make_batch(i))
        if i == start_step:
            first_loss = float(metrics["loss"])
        if (i + 1) % args.log_every == 0 or i + 1 == args.steps:
            last_loss = float(metrics["loss"])
            dt = time.time() - t0
            print(
                f"step {i+1:5d}  loss {last_loss:.4f}  gnorm "
                f"{float(metrics['grad_norm']):.3f}  lr {float(metrics['lr']):.2e}  "
                f"({dt:.1f}s)"
            )
        if saver and (i + 1) % args.ckpt_every == 0:
            saver.save_async(i + 1, state)
    if saver:
        saver.wait()
    print(f"done: loss {first_loss:.4f} → {last_loss:.4f}")
    return 0


def _stub_memory(cfg, batch, length, seed):
    return jax.random.normal(
        jax.random.PRNGKey(seed), (batch, length, cfg.d_model), jnp.float32
    ).astype(jnp.dtype(cfg.dtype))


if __name__ == "__main__":
    raise SystemExit(main())
