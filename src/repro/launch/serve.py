"""Serving driver: single-model batched generation or multi-tenant
reuse-serving (the paper's technique over LM pipelines).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --smoke
    PYTHONPATH=src python -m repro.launch.serve --reuse --tenants 6
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro import configs
from repro.models import init_params


def serve_model(args) -> int:
    from repro.serve.engine import Request, ServeEngine

    cfg = configs.get_smoke_config(args.arch) if args.smoke else configs.get_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    mem_len = {"vlm": cfg.num_image_tokens, "audio": cfg.encoder_seq}.get(cfg.family, 0)
    eng = ServeEngine(cfg, params, slots=args.slots, max_len=args.max_len)
    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, size=rng.integers(4, 12)).astype(np.int32)
        mem = rng.standard_normal((mem_len, cfg.d_model)).astype(np.float32) if mem_len else None
        eng.submit(Request(rid, prompt, max_new=args.max_new, memory=mem))
    results = eng.run()
    for r in sorted(results, key=lambda r: r.rid):
        print(f"req {r.rid}: prompt[{r.prompt_len}] → {r.tokens}")
    print(f"served {len(results)} requests")
    return 0


def serve_reuse(args) -> int:
    from repro.serve import ReuseServing, TenantPipeline

    rs = ReuseServing(strategy="signature", base_batch=args.slots)
    for i in range(args.tenants):
        rs.add_tenant(
            TenantPipeline(
                tenant=f"tenant{i}",
                stream=("urban", "meter", "taxi")[i % 3],
                shared_stages=3,
                n_stages=4,
                d=64,
                layers_per_stage=4,
            )
        )
    rs.run(args.ticks)
    s = rs.stats()
    naive = args.tenants * (4 + 3)  # stages + embed/head/sink per tenant… per source
    print(f"tenants={s['tenants']} running_tasks={s['running_tasks']} "
          f"deployed_cost={s['deployed_cost']:.1f}")
    for t in list(rs.tenants):
        print(t, rs.tenant_output(t))
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--reuse", action="store_true", help="multi-tenant reuse-serving")
    ap.add_argument("--tenants", type=int, default=6)
    ap.add_argument("--ticks", type=int, default=5)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=256)
    args = ap.parse_args(argv)
    return serve_reuse(args) if args.reuse else serve_model(args)


if __name__ == "__main__":
    raise SystemExit(main())
