"""Serving driver.

Front-end daemon mode (JAX-free on the dryrun backend):

    PYTHONPATH=src python -m repro.launch.serve start --port 7421 --slots 64
    PYTHONPATH=src python -m repro.launch.serve submit --port 7421 \\
        --tenant alice --workload opmw --count 5
    PYTHONPATH=src python -m repro.launch.serve status --port 7421 --stats
    PYTHONPATH=src python -m repro.launch.serve stop --port 7421

Legacy single-process modes (no subcommand):

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --smoke
    PYTHONPATH=src python -m repro.launch.serve --reuse --tenants 6
"""
from __future__ import annotations

import argparse
import json
import sys

_SUBCOMMANDS = ("start", "submit", "status", "stop")


# -- legacy single-process modes -------------------------------------------------


def serve_model(args) -> int:
    import jax
    import numpy as np

    from repro import configs
    from repro.models import init_params
    from repro.serve.engine import Request, ServeEngine

    cfg = configs.get_smoke_config(args.arch) if args.smoke else configs.get_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    mem_len = {"vlm": cfg.num_image_tokens, "audio": cfg.encoder_seq}.get(cfg.family, 0)
    eng = ServeEngine(cfg, params, slots=args.slots, max_len=args.max_len)
    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, size=rng.integers(4, 12)).astype(np.int32)
        mem = rng.standard_normal((mem_len, cfg.d_model)).astype(np.float32) if mem_len else None
        eng.submit(Request(rid, prompt, max_new=args.max_new, memory=mem))
    results = eng.run()
    for r in sorted(results, key=lambda r: r.rid):
        print(f"req {r.rid}: prompt[{r.prompt_len}] → {r.tokens}")
    print(f"served {len(results)} requests")
    return 0


def serve_reuse(args) -> int:
    from repro.serve import ReuseServing, TenantPipeline

    rs = ReuseServing(strategy="signature", base_batch=args.slots)
    for i in range(args.tenants):
        rs.add_tenant(
            TenantPipeline(
                tenant=f"tenant{i}",
                stream=("urban", "meter", "taxi")[i % 3],
                shared_stages=3,
                n_stages=4,
                d=64,
                layers_per_stage=4,
            )
        )
    rs.run(args.ticks)
    s = rs.stats()
    print(f"tenants={s['tenants']} running_tasks={s['running_tasks']} "
          f"deployed_cost={s['deployed_cost']:.1f}")
    for t in list(rs.tenants):
        print(t, rs.tenant_output(t))
    return 0


def legacy_main(argv) -> int:
    ap = argparse.ArgumentParser(prog="repro.launch.serve")
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--reuse", action="store_true", help="multi-tenant reuse-serving")
    ap.add_argument("--tenants", type=int, default=6)
    ap.add_argument("--ticks", type=int, default=5)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=256)
    args = ap.parse_args(argv)
    return serve_reuse(args) if args.reuse else serve_model(args)


# -- front-end daemon mode -------------------------------------------------------


def _addr_args(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, required=True)


def cmd_start(argv) -> int:
    ap = argparse.ArgumentParser(prog="repro.launch.serve start")
    _addr_args(ap)
    ap.add_argument("--slots", type=int, default=256)
    ap.add_argument("--backend", default="dryrun")
    ap.add_argument("--strategy", default="signature")
    ap.add_argument("--max-slots", type=int, default=64, help="per-tenant slot quota")
    ap.add_argument("--max-pending", type=int, default=16, help="per-tenant queue depth")
    ap.add_argument("--retry-after", type=float, default=0.5)
    ap.add_argument("--defrag-every", type=int, default=None,
                    help="defragment after every N removals")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=None)
    ap.add_argument("--restore", action="store_true",
                    help="restore session + ledgers from --checkpoint-dir")
    ap.add_argument("--step-interval", type=float, default=None,
                    help="step the data plane every S seconds while serving")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve Prometheus text over plain HTTP at /metrics "
                         "on this port (0 picks a free one)")
    ap.add_argument("--log-file", default=None)
    args = ap.parse_args(argv)

    import logging
    import threading

    from repro.serve.frontend import ServeFrontend, TenantQuota

    if args.log_file:
        logging.basicConfig(
            filename=args.log_file,
            level=logging.INFO,
            format="%(asctime)s %(name)s %(levelname)s %(message)s",
        )
    quota = TenantQuota(max_slots=args.max_slots, max_pending=args.max_pending)
    if args.restore:
        if not args.checkpoint_dir:
            ap.error("--restore needs --checkpoint-dir")
        frontend = ServeFrontend.restore(
            args.checkpoint_dir,
            slots=args.slots,
            default_quota=quota,
            retry_after=args.retry_after,
            defrag_every=args.defrag_every,
            host=args.host,
            port=args.port,
            metrics_port=args.metrics_port,
        )
    else:
        frontend = ServeFrontend(
            slots=args.slots,
            strategy=args.strategy,
            backend=args.backend,
            default_quota=quota,
            retry_after=args.retry_after,
            defrag_every=args.defrag_every,
            host=args.host,
            port=args.port,
            metrics_port=args.metrics_port,
            checkpoint_dir=args.checkpoint_dir,
            checkpoint_every=args.checkpoint_every,
        )
    host, port = frontend.start()
    print(f"serving on {host}:{port}", flush=True)
    if frontend._metrics_sock is not None:
        mhost, mport = frontend._metrics_sock.getsockname()[:2]
        print(f"metrics on http://{mhost}:{mport}/metrics", flush=True)

    stepper = None
    if args.step_interval:
        def _step_loop() -> None:
            while not frontend._shutdown_event.wait(args.step_interval):
                try:
                    frontend.step()
                except Exception:  # pragma: no cover - daemon resilience
                    logging.getLogger(__name__).exception("background step failed")

        stepper = threading.Thread(target=_step_loop, name="serve-stepper", daemon=True)
        stepper.start()
    try:
        frontend.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        frontend.close()
    return 0


def _workload(name: str):
    if name == "opmw":
        from repro.workloads import opmw_workload

        return opmw_workload()
    if name == "riot":
        from repro.workloads import riot_workload

        return riot_workload()
    raise SystemExit(f"unknown workload {name!r} (expected opmw or riot)")


def cmd_submit(argv) -> int:
    ap = argparse.ArgumentParser(prog="repro.launch.serve submit")
    _addr_args(ap)
    ap.add_argument("--tenant", required=True)
    ap.add_argument("--workload", default="opmw", help="opmw | riot")
    ap.add_argument("--count", type=int, default=1, help="dataflows to submit")
    ap.add_argument("--offset", type=int, default=0, help="skip the first N pool dataflows")
    ap.add_argument("--wait", action="store_true", help="sleep out RETRY_AFTER backpressure")
    args = ap.parse_args(argv)

    from repro.serve.client import ServeClient, SubmitTimeout
    from repro.workloads import tenant_copy

    pool = _workload(args.workload)
    picks = pool[args.offset: args.offset + args.count]
    if len(picks) < args.count:
        raise SystemExit(
            f"workload {args.workload!r} has {len(pool)} dataflows; "
            f"--offset {args.offset} --count {args.count} overruns it"
        )
    rc = 0
    with ServeClient((args.host, args.port)) as client:
        for df in picks:
            try:
                result = client.submit(
                    args.tenant, tenant_copy(df, args.tenant), wait=args.wait
                )
            except SubmitTimeout as e:
                print(json.dumps({"status": "TIMEOUT", "error": str(e)}), flush=True)
                rc = 1
                continue
            print(json.dumps(result), flush=True)
            if result.get("status") not in ("ADMITTED", "QUEUED"):
                rc = 1
    return rc


def cmd_status(argv) -> int:
    ap = argparse.ArgumentParser(prog="repro.launch.serve status")
    _addr_args(ap)
    ap.add_argument("--stats", action="store_true", help="include per-tenant ledgers")
    ap.add_argument("--tenant", default=None)
    args = ap.parse_args(argv)

    from repro.serve.client import ServeClient

    with ServeClient((args.host, args.port)) as client:
        out = client.stats(args.tenant) if args.stats or args.tenant else client.status()
    print(json.dumps(out, indent=2, sort_keys=True))
    return 0


def cmd_stop(argv) -> int:
    ap = argparse.ArgumentParser(prog="repro.launch.serve stop")
    _addr_args(ap)
    ap.add_argument("--no-drain", action="store_true", help="skip the final fair-share drain")
    ap.add_argument("--no-checkpoint", action="store_true")
    args = ap.parse_args(argv)

    from repro.serve.client import ServeClient

    with ServeClient((args.host, args.port)) as client:
        if not args.no_drain:
            client.drain()
        out = client.shutdown(checkpoint=not args.no_checkpoint)
    print(json.dumps(out))
    return 0


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if argv and argv[0] in _SUBCOMMANDS:
        handler = {
            "start": cmd_start,
            "submit": cmd_submit,
            "status": cmd_status,
            "stop": cmd_stop,
        }[argv[0]]
        return handler(argv[1:])
    return legacy_main(argv)


if __name__ == "__main__":
    raise SystemExit(main())
