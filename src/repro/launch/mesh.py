"""Production meshes.

``make_production_mesh`` is a function (not a module constant) so importing
this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init, and smoke tests must keep seeing 1 device.

Mesh shapes (TPU v5e):
  single-pod  (16, 16)     axes ("data", "model")   — 256 chips
  multi-pod   (2, 16, 16)  axes ("pod", "data", "model") — 512 chips

The ``pod`` axis is an outer data-parallel axis: gradient all-reduce
crosses pods once per step (DCN-friendly); weights/optimizer shard over
(data × model) *within* a pod so no parameter collective crosses the DCN.
"""
from __future__ import annotations

from typing import Dict

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_sizes(mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def make_host_mesh():
    """1-device mesh for CPU smoke paths (axes exist, sizes 1)."""
    return jax.make_mesh((1, 1), ("data", "model"))
