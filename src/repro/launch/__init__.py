"""Launch layer: production meshes, per-cell input specs, dry-run driver,
and the train/serve entrypoints."""
