"""Per-cell stand-in inputs (ShapeDtypeStruct — zero allocation) and the
sharding assembly for every (architecture × input-shape × mesh) cell.

``build_cell`` returns everything the dry-run needs:
  * the step function (train / prefill / decode) closed over the config,
  * abstract inputs,
  * in/out shardings (NamedSharding trees),
  * the AxisRules whose activation constraints the step body reads.

Memory policy (v5e, 16 GB HBM/chip):
  * params + AdamW state shard over (fsdp=data × model); moments bf16/f32
    per config size (see ``_opt_for``).
  * training microbatches: accum = global_batch / data-size ⇒ one sequence
    per data shard per microstep; remat everywhere ⇒ live set is one layer.
  * the residual stream is sequence-parallel: ``hidden`` rule shards S over
    the model axis, so the per-layer saved activations are 1/16th.
  * KV caches shard batch over data and head_dim (or kv-heads / latent
    positions) over model — see models/sharding.cache_specs.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.configs import ShapeCell
from repro.models import (
    abstract_cache,
    abstract_params,
    decode_step,
    prefill,
)
from repro.models import sharding as shd
from repro.models.config import ModelConfig
from repro.train import AdamWConfig, abstract_train_state, make_train_step

from .mesh import mesh_sizes

PyTree = Any


@dataclass
class Cell:
    arch: str
    cfg: ModelConfig
    cell: ShapeCell
    step_fn: Callable
    abstract_inputs: Tuple[PyTree, ...]
    in_shardings: Tuple[PyTree, ...]
    out_shardings: PyTree
    rules: shd.AxisRules
    accum: int = 1
    # donated arg positions: train donates the state, serve donates the cache
    donate: Tuple[int, ...] = ()


def _opt_for(cfg: ModelConfig) -> Tuple[AdamWConfig, str]:
    """(optimizer config, grad-accum dtype) sized to 16 GB/chip HBM."""
    total, _ = cfg.param_count()
    # ≥100B params: bf16 moments to stay inside 16 GB/chip (DESIGN.md §7)
    if total > 100e9:
        return AdamWConfig(mu_dtype="bfloat16", nu_dtype="bfloat16"), "bfloat16"
    return AdamWConfig(mu_dtype="float32", nu_dtype="float32"), "float32"


def _data_axes_for(batch: int, rules: shd.AxisRules) -> Tuple[str, ...]:
    """Largest prefix of the data axes whose product divides the batch."""
    axes: Tuple[str, ...] = ()
    prod = 1
    for a in rules.data:
        if batch % (prod * rules.mesh_sizes[a]) == 0:
            axes += (a,)
            prod *= rules.mesh_sizes[a]
    return axes


def make_rules(mesh, *, seq_parallel: bool = True) -> shd.AxisRules:
    sizes = mesh_sizes(mesh)
    rules = shd.AxisRules(sizes)
    rules.mesh = mesh
    if seq_parallel:
        # sequence-parallel residual stream: saved per-layer activations
        # are 1/|model| per chip (Korthikanti et al., adapted to GSPMD)
        rules.activation_rules["hidden"] = P(rules.data, "model", None)
    rules.activation_rules["moe_experts"] = P(None, rules.data, None)
    # Expert-parallel MoE is the default under a mesh (§Perf H1): experts
    # stationary over the data axis, F over model; shard-local dispatch.
    # GSPMD's scatter dispatch replicates the (T·K, D) gather per device
    # (data-dependent indices defeat propagation) — available for
    # comparison via --experiment moe_gspmd.
    rules.role_overrides.update(
        {
            "w_up#4": {-3: ["data"], -2: [None], -1: ["model"]},
            "w_gate#4": {-3: ["data"], -2: [None], -1: ["model"]},
            "w_down#4": {-3: ["data"], -2: ["model"], -1: [None]},
            "w_up#3": {-2: [None], -1: ["model"]},
            "w_gate#3": {-2: [None], -1: ["model"]},
            "w_down#3": {-2: ["model"], -1: [None]},
            "router": {},
        }
    )
    return rules


def _batched_spec(batch: int, rules: shd.AxisRules, trailing: int) -> P:
    axes = _data_axes_for(batch, rules)
    lead = axes if len(axes) > 1 else (axes[0] if axes else None)
    return P(lead, *([None] * trailing))


def _memory_struct(cfg: ModelConfig, batch: int) -> Optional[jax.ShapeDtypeStruct]:
    if cfg.family == "vlm":
        return jax.ShapeDtypeStruct((batch, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "audio":
        return jax.ShapeDtypeStruct((batch, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    return None


def input_specs(arch: str, shape: str) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of a cell."""
    cfg = configs.get_config(arch)
    cell = configs.shape_cell(shape)
    B, S = cell.global_batch, cell.seq_len
    tok = jax.ShapeDtypeStruct
    if cell.kind == "train":
        out = {
            "tokens": tok((B, S), jnp.int32),
            "labels": tok((B, S), jnp.int32),
        }
        mem = _memory_struct(cfg, B)
        if mem is not None:
            out["memory"] = mem
        return out
    if cell.kind == "prefill":
        out = {"tokens": tok((B, S), jnp.int32)}
        mem = _memory_struct(cfg, B)
        if mem is not None:
            out["memory"] = mem
        return out
    # decode: one new token against a cache of S absolute positions
    return {"tokens": tok((B, 1), jnp.int32)}


def build_cell(arch: str, shape: str, mesh, *, overrides: Optional[dict] = None) -> Cell:
    cfg = configs.get_config(arch)
    cell = configs.shape_cell(shape)
    skip = configs.cell_supported(cfg, cell)
    if skip:
        raise ValueError(f"{arch}×{shape}: {skip}")
    rules = make_rules(mesh)
    if cfg.family == "moe":
        from repro.models import mlp as _mlp

        ep_axis_size = rules.mesh_sizes[rules.data[-1]]
        if cfg.moe.num_experts % ep_axis_size == 0:
            _mlp.MOE_IMPL = "ep"  # default under a mesh; see make_rules
        else:
            # E < |data| (mixtral: 8 experts, 16-way axis) — keep the GSPMD
            # dispatch; grouped-EP (expert padding / hierarchical
            # all_to_all) is the documented extension (§Perf H1 notes)
            _mlp.MOE_IMPL = "dense"
            for k in list(rules.role_overrides):
                if k.endswith("#4"):
                    del rules.role_overrides[k]
    # batch-aware activation rules: a batch dim only takes the data axes
    # whose product divides it (long_500k decodes with global_batch=1)
    lead_axes = _data_axes_for(cell.global_batch, rules)
    lead = lead_axes if len(lead_axes) > 1 else (lead_axes[0] if lead_axes else None)
    rules.activation_rules["hidden"] = P(lead, "model", None)
    rules.activation_rules["decode_hidden"] = P(lead, None, None)
    rules.activation_rules["logits"] = P(lead, None, "model")
    rules.activation_rules["logits_last"] = P(lead, "model")
    if overrides:
        for k, v in (overrides.get("activation_rules") or {}).items():
            rules.activation_rules[k] = v
        rules.role_overrides.update(overrides.get("role_overrides") or {})
        if overrides.get("decode_cache_layout"):
            from repro.models import decode as _dec

            _dec.CACHE_LAYOUT = overrides["decode_cache_layout"]
        if overrides.get("moe_decode"):
            from repro.models import mlp as _mlp

            _mlp.MOE_DECODE = overrides["moe_decode"]
        if overrides.get("moe_impl"):
            from repro.models import mlp as _mlp

            _mlp.MOE_IMPL = overrides["moe_impl"]

    params_abs = abstract_params(cfg)
    param_specs = shd.infer_param_specs(params_abs, rules)
    B, S = cell.global_batch, cell.seq_len
    mem_len = {"vlm": cfg.num_image_tokens, "audio": cfg.encoder_seq}.get(cfg.family, 0)

    if cell.kind == "train":
        opt, accum_dtype = _opt_for(cfg)
        dsize = 1
        for a in rules.data:
            dsize *= rules.mesh_sizes[a]
        accum = (overrides or {}).get("accum", max(1, B // dsize))
        while B % accum or (B // accum) % dsize:
            accum -= 1  # fall back to a divisor
        state_abs = abstract_train_state(cfg, opt)
        state_specs = {
            "step": P(),
            "params": param_specs,
            "mu": param_specs,
            "nu": param_specs,
        }
        batch_abs = input_specs(arch, shape)
        batch_specs = {
            "tokens": _batched_spec(B, rules, 1),
            "labels": _batched_spec(B, rules, 1),
        }
        if "memory" in batch_abs:
            batch_specs["memory"] = _batched_spec(B, rules, 2)
        step = make_train_step(cfg, opt, accum=accum, accum_dtype=accum_dtype)

        def train_fn(state, batch):
            with shd.use_rules(rules):
                return step(state, batch)

        metrics_specs = {"loss": P(), "grad_norm": P(), "lr": P()}
        return Cell(
            arch, cfg, cell, train_fn,
            (state_abs, batch_abs),
            (_ns(mesh, state_specs, state_abs), _ns(mesh, batch_specs, batch_abs)),
            _ns(mesh, (state_specs, metrics_specs), None),
            rules, accum, donate=(0,),
        )

    if cell.kind == "prefill":
        cache_abs = abstract_cache(cfg, B, S, memory_len=mem_len)
        cache_spec = shd.cache_specs(cache_abs, rules)
        batch_abs = input_specs(arch, shape)
        ins_abs = (params_abs, batch_abs["tokens"], cache_abs)
        ins_specs = (param_specs, _batched_spec(B, rules, 1), cache_spec)
        if "memory" in batch_abs:
            def prefill_fn(params, tokens, cache, memory):
                with shd.use_rules(rules):
                    return prefill(params, cfg, tokens, cache, memory=memory)

            ins_abs += (batch_abs["memory"],)
            ins_specs += (_batched_spec(B, rules, 2),)
        else:
            def prefill_fn(params, tokens, cache):
                with shd.use_rules(rules):
                    return prefill(params, cfg, tokens, cache)

        out_specs = (rules.activation_rules["logits_last"], cache_spec)
        return Cell(
            arch, cfg, cell, prefill_fn,
            ins_abs, _ns(mesh, ins_specs, ins_abs),
            _ns(mesh, out_specs, None), rules, donate=(2,),
        )

    # decode: cache holds S absolute positions (ring-bounded under SWA)
    cache_abs = abstract_cache(cfg, B, S + 8, memory_len=mem_len)
    cache_spec = shd.cache_specs(cache_abs, rules)
    batch_abs = input_specs(arch, shape)

    def decode_fn(params, tokens, cache):
        with shd.use_rules(rules):
            return decode_step(params, cfg, tokens, cache)

    ins_abs = (params_abs, batch_abs["tokens"], cache_abs)
    ins_specs = (param_specs, _batched_spec(B, rules, 1), cache_spec)
    out_specs = (rules.activation_rules["logits_last"], cache_spec)
    return Cell(
        arch, cfg, cell, decode_fn,
        ins_abs, _ns(mesh, ins_specs, ins_abs),
        _ns(mesh, out_specs, None), rules, donate=(2,),
    )


def _ns(mesh, spec_tree: PyTree, abs_tree: Optional[PyTree]) -> PyTree:
    """PartitionSpec tree → NamedSharding tree."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def build_pp_decode_cell(arch: str, shape: str, mesh) -> Cell:
    """§Perf experiment: pipeline-parallel decode (dense family).

    Layers shard over the data axis (weights stationary per stage);
    microbatches flow between stages via collective_permute. One call =
    one steady-state GPipe round (per-token throughput cost).
    """
    cfg = configs.get_config(arch)
    cell = configs.shape_cell(shape)
    assert cell.kind == "decode" and cfg.family == "dense"
    rules = make_rules(mesh)
    B, S = cell.global_batch, cell.seq_len

    params_abs = abstract_params(cfg)
    base_specs = shd.infer_param_specs(params_abs, rules)

    def strip_data(spec):
        clean = []
        for p in tuple(spec):
            if p is None:
                clean.append(None)
            elif isinstance(p, tuple):
                kept = tuple(a for a in p if a != "data")
                clean.append(kept if kept else None)
            else:
                clean.append(None if p == "data" else p)
        return clean

    def pp_spec(path, spec):
        keys = [str(getattr(p, "key", p)) for p in path]
        if keys and keys[0] == "blocks":
            rest = strip_data(spec)[1:]
            return P("data", *rest)
        return P(*strip_data(spec))

    param_specs = jax.tree_util.tree_map_with_path(
        pp_spec, base_specs, is_leaf=lambda x: isinstance(x, P)
    )

    from repro.models import decode as dec

    cache_abs = dict(abstract_cache(cfg, B, S + 8))
    cache_abs["pp_h"] = jax.ShapeDtypeStruct((B, 1, cfg.d_model), jnp.dtype(cfg.dtype))

    def cache_pp_spec(path, leaf):
        keys = [str(getattr(p, "key", p)) for p in path]
        if keys and keys[0] == "layers":
            # (L, B, S, KV, hd): L over stages, head_dim over model
            # (S-over-model was tried and regressed — see §Perf H2 log)
            out = ["data"] + [None] * (len(leaf.shape) - 1)
            if leaf.shape[-1] % rules.mesh_sizes.get("model", 1) == 0:
                out[-1] = "model"
            return P(*out)
        if keys and keys[0] == "pp_h":
            return P("data", None, None)
        return P()

    cache_spec = jax.tree_util.tree_map_with_path(cache_pp_spec, cache_abs)
    batch_abs = input_specs(arch, shape)

    def pp_fn(params, tokens, cache):
        with shd.use_rules(rules):
            return dec.decode_step_pp(params, cfg, tokens, cache, rules)

    ins_abs = (params_abs, batch_abs["tokens"], cache_abs)
    ins_specs = (param_specs, P("data", None), cache_spec)
    out_specs = (P("data", None), cache_spec)
    return Cell(
        arch, cfg, cell, pp_fn,
        ins_abs, _ns(mesh, ins_specs, ins_abs),
        _ns(mesh, out_specs, None), rules, donate=(2,),
    )
