"""Named §Perf experiments: override sets applied on top of the baseline
sharding/accum policy by ``dryrun --experiment NAME``.

Each entry documents its hypothesis; results land in EXPERIMENTS.md §Perf
as hypothesis → change → before → after → confirmed/refuted.
"""
from __future__ import annotations

from typing import Any, Dict

from jax.sharding import PartitionSpec as P

# Axis-kind tokens understood by models.sharding role tables
MODEL, FSDP, DATA = "model", "fsdp", "data"


def get(name: str) -> Dict[str, Any]:
    return dict(_EXPERIMENTS[name])


def names():
    return sorted(_EXPERIMENTS)


def _moe_ep(accum: int) -> Dict[str, Any]:
    """Expert parallelism via shard_map (see the hypothesis below)."""
    return {
        "accum": accum,
        "moe_impl": "ep",
        "role_overrides": {
            # stacked experts (L, E, D, F): E over data, F over model
            "w_up#4": {-3: [DATA], -2: [None], -1: [MODEL]},
            "w_gate#4": {-3: [DATA], -2: [None], -1: [MODEL]},
            "w_down#4": {-3: [DATA], -2: [MODEL], -1: [None]},
            # shared-expert / first-dense mlp (L, D, F): F over model only
            "w_up#3": {-2: [None], -1: [MODEL]},
            "w_gate#3": {-2: [None], -1: [MODEL]},
            "w_down#3": {-2: [MODEL], -1: [None]},
            "router": {},  # replicated (small)
        },
    }


_EXPERIMENTS: Dict[str, Dict[str, Any]] = {
    # ---- deepseek-v2 train_4k (most collective-bound) -----------------------
    # H1: with accum=16, the (data-axis) FSDP weight all-gather repeats 16×
    # per step; 236B × 2B / 16 (model) × 15/16 × 16 microbatches × fwd+bwd
    # ≈ 27 TB/device — the dominant collective term. Fewer microbatches
    # divide it directly; activations stay sequence-parallel so the memory
    # cost of bigger microbatches is bounded.
    "accum2": {"accum": 2},
    "accum4": {"accum": 4},
    "accum8": {"accum": 8},
    # H2: expert parallelism via shard_map — shard the expert dim over the
    # data axis instead of FSDP-sharding every expert's matrices. Expert
    # weights become *stationary* (each data shard owns E/16 experts whole,
    # F still over model); the dispatch becomes shard-local scatters + one
    # all_to_all each way (T·K·cf·D), killing the GSPMD scatter's
    # cross-shard all-reduces (~38 TB/device at 236B).
    "moe_ep_accum2": _moe_ep(2),
    "moe_ep_accum4": _moe_ep(4),
    "moe_ep_accum8": _moe_ep(8),
    # the pre-EP GSPMD scatter dispatch (the original baseline) for A/B
    "moe_gspmd": {
        "moe_impl": "dense",
        "role_overrides": {
            "w_up#4": {-3: [None], -2: [FSDP], -1: [MODEL]},
            "w_gate#4": {-3: [None], -2: [FSDP], -1: [MODEL]},
            "w_down#4": {-3: [None], -2: [MODEL], -1: [FSDP]},
            "w_up#3": {-2: [FSDP], -1: [MODEL]},
            "w_gate#3": {-2: [FSDP], -1: [MODEL]},
            "w_down#3": {-2: [MODEL], -1: [FSDP]},
            "router": {-2: [FSDP], -1: [None]},
        },
    },
    # ---- nemotron decode_32k (paper-representative serving cell) ------------
    # H: the per-layer KV cache slices scanned as xs/ys are copied every
    # step; carrying the stacked cache through the loop and updating it
    # in place (donation-friendly DUS on the carry) removes the copy.
    "carry_cache": {"decode_cache_layout": "carry"},
    # H2: pipeline-parallel decode — layers shard over the data axis
    # (each stage owns L/16 layers whole, model-TP'd), so weights are
    # STATIONARY; one collective_permute of (B/16, 1, D) per round replaces
    # re-gathering 42 GB/device of weights per token. One call = one
    # steady-state GPipe round.
    "decode_pp": {"decode_pp": True},
    # ---- mixtral long_500k (worst roofline fraction) -------------------------
    # H: at B=1 decode, the dense-capacity MoE path computes all 8 experts;
    # top-2 gather of expert weights cuts weight traffic ~4×.
    "moe_decode_sparse": {"moe_decode": "sparse"},
    "sparse_carry": {"moe_decode": "sparse", "decode_cache_layout": "carry"},
}
