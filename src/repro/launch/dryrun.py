import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("REPRO_EXTRA_XLA_FLAGS", "")
).strip()

"""Dry-runs: model-compile cells and dataflow-trace simulations.

Mode 1 (model cells) — ``lower() + compile()`` every (architecture ×
input-shape × mesh) cell on placeholder devices, and extract the roofline
terms from the compiled artifact.

The two lines above MUST stay first — jax locks the device count on first
init. Run one cell per process (the CLI default) so device state and
compile memory stay isolated:

    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-20b \
        --shape train_4k [--multi-pod] [--json out.json]

or sweep everything (spawns one subprocess per cell):

    PYTHONPATH=src python -m repro.launch.dryrun --all --out-dir results/dryrun

Mode 2 (dataflow traces) — replay an OPMW/RIoT arrival-departure trace
through the ExecutionBackend data plane behind ``repro.api.ReuseSession``.
With the default ``--backend dryrun`` this never initializes JAX (the
registry resolves backends lazily), so a full 35-dataflow sweep answers
in milliseconds — the control-plane capacity-planning companion to the
compile cells:

    PYTHONPATH=src python -m repro.launch.dryrun --trace opmw/rw1 \
        [--backend dryrun] [--steps-per-event 1] [--json out.json]

Trace mode is crash-recoverable: ``--checkpoint-dir DIR`` writes one
durable checkpoint every ``--checkpoint-every`` events (default 1), and
``--restore`` resumes an interrupted trace from the newest valid
checkpoint — the control-plane journal length tells the CLI how many
events were already applied, so the replay continues exactly where the
crashed run stopped (``--max-events`` truncates a run, which is also how
the recovery tests simulate the crash):

    PYTHONPATH=src python -m repro.launch.dryrun --trace opmw/rw1 \
        --checkpoint-dir /tmp/ckpts --max-events 40
    PYTHONPATH=src python -m repro.launch.dryrun --trace opmw/rw1 \
        --checkpoint-dir /tmp/ckpts --restore
"""
import argparse
import json
import re
import subprocess
import sys
import time
from typing import Any, Dict, Optional


def run_cell(
    arch: str,
    shape: str,
    multi_pod: bool,
    overrides: Optional[dict] = None,
    top_sites: int = 0,
) -> Dict[str, Any]:
    import jax

    from repro import configs
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import build_cell
    from repro.roofline.analysis import analyze_compiled

    cfg = configs.get_config(arch)
    cell = configs.shape_cell(shape)
    skip = configs.cell_supported(cfg, cell)
    rec: Dict[str, Any] = {
        "arch": arch,
        "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "multi_pod": multi_pod,
    }
    if skip:
        rec["status"] = skip
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    if overrides and overrides.get("decode_pp"):
        from repro.launch.specs import build_pp_decode_cell

        built = build_pp_decode_cell(arch, shape, mesh)
    else:
        built = build_cell(arch, shape, mesh, overrides=overrides)
    t0 = time.time()
    with mesh:
        lowered = jax.jit(
            built.step_fn,
            in_shardings=built.in_shardings,
            out_shardings=built.out_shardings,
            donate_argnums=built.donate,
        ).lower(*built.abstract_inputs)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # older JAX wraps the dict in a list
        cost = cost[0] if cost else {}
    print(f"memory_analysis: {mem}")
    print(
        "cost_analysis: flops=%.4g bytes=%.4g"
        % (cost.get("flops", 0.0), cost.get("bytes accessed", 0.0))
    )
    rec.update(
        status="ok",
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        accum=built.accum,
        memory={
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "generated_code_bytes": int(getattr(mem, "generated_code_size_in_bytes", 0)),
        },
        roofline=analyze_compiled(compiled, cfg, cell, mesh),
    )
    if top_sites:
        from repro.roofline import hlo_parse

        parsed = hlo_parse.analyze(compiled.as_text(), top_k=top_sites)
        rec["hbm_top_sites"] = parsed["hbm_top_sites"]
    return rec


def run_dataflow_trace(
    spec: str,
    backend: Optional[str] = None,
    strategy: str = "signature",
    steps_per_event: int = 1,
    checkpoint_dir: Optional[str] = None,
    checkpoint_every: int = 1,
    checkpoint_keep_last: Optional[int] = None,
    checkpoint_background: bool = False,
    restore: bool = False,
    max_events: Optional[int] = None,
    step_mode: Optional[str] = None,
    max_workers: Optional[int] = None,
    transport: Optional[str] = None,
    workers: Optional[int] = None,
    supervise: bool = False,
    autoscale: Optional[Dict[str, Any]] = None,
    kill_worker_at: Optional[int] = None,
    kill_worker: int = 0,
    trace_out: Optional[str] = None,
    metrics_out: Optional[str] = None,
) -> Dict[str, Any]:
    """Replay ``workload/trace`` (e.g. ``opmw/rw1``) on an ExecutionBackend.

    With ``checkpoint_dir`` the session checkpoints durably every
    ``checkpoint_every`` events (pruned to the newest
    ``checkpoint_keep_last`` valid ones when set); ``restore=True`` resumes
    from the newest valid checkpoint, skipping the events the crashed run
    already applied (one journal op per trace event, so the journal length
    *is* the resume offset). ``max_events`` truncates the replay — the
    crash simulator. ``step_mode="concurrent"`` steps the deployment
    through the dependency-aware wave pipeline (on the dry-run backend the
    per-step ``makespan_ms`` then models concurrent wall-clock: wave max,
    not wave sum).

    Cluster-plane knobs (``backend="multiproc"`` only): ``supervise``
    arms self-healing worker supervision, ``autoscale`` passes
    :class:`~repro.cluster.AutoscalePolicy` kwargs, and
    ``kill_worker_at=N`` SIGKILLs worker ``kill_worker`` after trace
    event ``N`` — the CI chaos smoke: the supervisor must recover it and
    the replay must still complete.

    Telemetry (``repro.obs``): ``trace_out=PATH`` arms span tracing and
    writes a Chrome/Perfetto trace of the whole replay;
    ``metrics_out=PATH`` writes one final Prometheus text scrape. Both
    export before the session closes so multiproc worker spans/metrics
    are harvested over RPC.
    """
    from repro.api import ReuseSession
    from repro.workloads import (
        opmw_workload,
        replay,
        riot_workload,
        rw_trace,
        seq_trace,
    )

    workload, _, trace = spec.partition("/")
    makers = {"opmw": opmw_workload, "riot": riot_workload}
    if workload not in makers or trace not in ("seq", "rw1", "rw2"):
        raise SystemExit(f"--trace must be {{opmw,riot}}/{{seq,rw1,rw2}}, got {spec!r}")
    dags = makers[workload]()
    seeds = {"seq": 3, "rw1": 11, "rw2": 23}
    events = (
        seq_trace(dags, seed=seeds[trace])
        if trace == "seq"
        else rw_trace(dags, seed=seeds[trace])
    )

    resumed_at = 0
    if restore:
        if not checkpoint_dir:
            raise SystemExit("--restore needs --checkpoint-dir")
        # backend=None honors the checkpointed backend; an explicit
        # --backend requests a cross-backend restore (inprocess ⇄ dryrun).
        # Likewise step_mode=None resumes in the checkpointed mode and an
        # explicit --step-mode restores a sync checkpoint into the
        # concurrent pipeline (or back) — the dependency DAG is rebuilt.
        session = ReuseSession.restore(
            checkpoint_dir,
            backend=backend,
            step_mode=step_mode,
            max_workers=max_workers,
            checkpoint_keep_last=checkpoint_keep_last,
            checkpoint_background=checkpoint_background or None,
            transport=transport,
            workers=workers,
            supervise=supervise,
            autoscale=autoscale,
        )
        resumed_at = len(session.manager.journal)  # events already applied
    else:
        session = ReuseSession(
            strategy=strategy,
            execute=True,
            backend=backend or "dryrun",
            checkpoint_dir=checkpoint_dir,
            checkpoint_keep_last=checkpoint_keep_last if checkpoint_dir else None,
            checkpoint_background=(checkpoint_background or None) if checkpoint_dir else None,
            step_mode=step_mode,
            max_workers=max_workers,
            transport=transport,
            workers=workers,
            supervise=supervise,
            autoscale=autoscale,
        )
    if trace_out:
        session.enable_tracing()
    todo = events[resumed_at:]
    if max_events is not None:
        todo = todo[: max(0, max_events - resumed_at)]
    live, paused, cost, makespan = [], [], [], []
    t0 = time.time()
    # close() even on a failing replay: it flushes background checkpoints
    # and stops worker processes / shm session dirs (a crashed multiproc
    # trace must not leak orphan workers into the CI runner)
    try:
        for i, _ in enumerate(replay(session, dags, todo)):
            if kill_worker_at is not None and i == kill_worker_at:
                import signal

                be = session._system.backend
                victim = kill_worker % max(getattr(be, "n_workers", 1), 1)
                os.kill(be._procs[victim].pid, signal.SIGKILL)
            report = None
            for _ in range(steps_per_event):
                report = session.step()
            if report is None:  # steps_per_event=0: account without stepping
                l, p, c = session._system.backend.account()
                m = 0.0
            else:
                l, p, c = report.live_tasks, report.paused_tasks, report.cost
                m = report.makespan_ms
            live.append(l)
            paused.append(p)
            cost.append(round(c, 4))
            makespan.append(round(m, 4))
            # Checkpoint on event boundaries (not raw steps) so a restore
            # resumes exactly at the next un-applied trace event.
            if checkpoint_dir and (i + 1) % max(1, checkpoint_every) == 0:
                session.checkpoint()
        backend_obj = session._system.backend
        record_step_mode = backend_obj.step_mode
        transport_name = getattr(getattr(backend_obj, "transport", None), "name", None)
        workers_n = getattr(backend_obj, "n_workers", None)
        backend_name = session.backend_name
        strategy_name = session.strategy
        health = session.worker_health()
        trace_spans = None
        if trace_out:
            trace_spans = session.export_chrome_trace(trace_out)
        if metrics_out:
            text = session.prometheus_text()
            os.makedirs(os.path.dirname(metrics_out) or ".", exist_ok=True)
            with open(metrics_out, "w") as f:
                f.write(text)
    finally:
        session.close()
    return {
        "trace_out": trace_out,
        "trace_spans": trace_spans,
        "metrics_out": metrics_out,
        "trace": spec,
        "backend": backend_name,
        "strategy": strategy_name,
        "step_mode": record_step_mode,
        "transport": transport_name,
        "workers": workers_n,
        "events": len(events),
        "events_applied": resumed_at + len(todo),
        "resumed_at_event": resumed_at,
        "wall_s": round(time.time() - t0, 3),
        "peak_live_tasks": max(live) if live else 0,
        "peak_paused_tasks": max(paused) if paused else 0,
        "peak_cores": max(cost) if cost else 0.0,
        "peak_makespan_ms": max(makespan) if makespan else 0.0,
        "worker_health": health,
        "series": {
            "live_tasks": live,
            "paused_tasks": paused,
            "cores": cost,
            "makespan_ms": makespan,
        },
    }


def _parse_autoscale(spec: Optional[str]) -> Optional[Dict[str, Any]]:
    """``"MIN:MAX"`` -> AutoscalePolicy kwargs (None passes through)."""
    if not spec:
        return None
    try:
        lo, _, hi = spec.partition(":")
        return {"min_workers": int(lo), "max_workers": int(hi)}
    except ValueError:
        raise SystemExit(f"--autoscale wants MIN:MAX (e.g. 1:4), got {spec!r}") from None


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--trace", help="dataflow-trace mode: {opmw,riot}/{seq,rw1,rw2}")
    ap.add_argument(
        "--backend", default=None,
        help="ExecutionBackend for --trace (default: dryrun; with --restore, "
        "the checkpointed backend unless set explicitly)",
    )
    ap.add_argument("--strategy", default="signature", help="merge strategy for --trace")
    ap.add_argument("--steps-per-event", type=int, default=1)
    ap.add_argument("--checkpoint-dir", help="durable checkpoints for --trace mode")
    ap.add_argument(
        "--checkpoint-every", type=int, default=1,
        help="checkpoint cadence in trace events (with --checkpoint-dir)",
    )
    ap.add_argument(
        "--checkpoint-keep-last", type=int, default=None,
        help="retain only the newest N valid checkpoints (GC; torn files reaped)",
    )
    ap.add_argument(
        "--restore", action="store_true",
        help="resume the trace from the newest valid checkpoint in --checkpoint-dir",
    )
    ap.add_argument(
        "--step-mode", choices=("sync", "concurrent"), default=None,
        help="data-plane stepping pipeline for --trace (default: sync; "
        "with --restore, the checkpointed mode unless set explicitly)",
    )
    ap.add_argument(
        "--max-workers", type=int, default=None,
        help="thread-pool width for --step-mode concurrent on jit backends",
    )
    ap.add_argument(
        "--transport", choices=("inproc", "shm", "tcp"), default=None,
        help="stream transport for --trace (default: the backend's own; "
        "multiproc defaults to shm)",
    )
    ap.add_argument(
        "--workers", type=int, default=None,
        help="worker-process pool size for --backend multiproc",
    )
    ap.add_argument(
        "--supervise", action="store_true",
        help="arm the cluster plane on --backend multiproc: heartbeat "
        "supervision, crash/hang recovery, shadow-snapshot redeploys",
    )
    ap.add_argument(
        "--autoscale", default=None, metavar="MIN:MAX",
        help="EWMA-driven worker-pool autoscaling bounds for --backend "
        "multiproc (e.g. 1:4)",
    )
    ap.add_argument(
        "--kill-worker-at", type=int, default=None, metavar="EVENT",
        help="chaos smoke: SIGKILL --kill-worker after trace event N "
        "(pair with --supervise; the run must still complete)",
    )
    ap.add_argument(
        "--kill-worker", type=int, default=0,
        help="which worker --kill-worker-at kills (default 0)",
    )
    ap.add_argument(
        "--checkpoint-background", action="store_true",
        help="write checkpoints on a background thread (snapshot on the "
        "stepping thread, encode/fsync/rename off-thread)",
    )
    ap.add_argument(
        "--max-events", type=int, default=None,
        help="stop the trace after N events (crash simulation / smoke)",
    )
    ap.add_argument(
        "--trace-out", default=None, metavar="PATH",
        help="arm span tracing and write a Chrome/Perfetto trace of the "
        "replay (load in chrome://tracing or ui.perfetto.dev)",
    )
    ap.add_argument(
        "--metrics-out", default=None, metavar="PATH",
        help="write one final Prometheus text scrape of the telemetry "
        "registry when the trace completes",
    )
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--experiment", help="named §Perf override set (launch/experiments.py)")
    ap.add_argument("--top-sites", type=int, default=0, help="report top-N HBM sites")
    ap.add_argument("--json", help="write the cell record to this path")
    ap.add_argument("--all", action="store_true", help="sweep all cells (subprocesses)")
    ap.add_argument("--meshes", default="single,multi", help="for --all")
    ap.add_argument("--out-dir", default="results/dryrun")
    ap.add_argument("--timeout", type=int, default=7200)
    args = ap.parse_args(argv)

    if args.trace:
        rec = run_dataflow_trace(
            args.trace,
            backend=args.backend,
            strategy=args.strategy,
            steps_per_event=args.steps_per_event,
            checkpoint_dir=args.checkpoint_dir,
            checkpoint_every=args.checkpoint_every,
            checkpoint_keep_last=args.checkpoint_keep_last,
            checkpoint_background=args.checkpoint_background,
            restore=args.restore,
            max_events=args.max_events,
            step_mode=args.step_mode,
            max_workers=args.max_workers,
            transport=args.transport,
            workers=args.workers,
            supervise=args.supervise,
            autoscale=_parse_autoscale(args.autoscale),
            kill_worker_at=args.kill_worker_at,
            kill_worker=args.kill_worker,
            trace_out=args.trace_out,
            metrics_out=args.metrics_out,
        )
        summary = {k: v for k, v in rec.items() if k != "series"}
        print(json.dumps(summary, indent=2))
        if args.json:
            os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
            with open(args.json, "w") as f:
                json.dump(rec, f, indent=1)
        return 0

    if args.all:
        return sweep(args)

    assert args.arch and args.shape, "--arch and --shape required (or --all)"
    overrides = None
    if args.experiment:
        from repro.launch import experiments

        overrides = experiments.get(args.experiment)
    rec = run_cell(
        args.arch, args.shape, args.multi_pod, overrides=overrides,
        top_sites=args.top_sites,
    )
    if args.experiment:
        rec["experiment"] = args.experiment
    out = json.dumps(rec, indent=2)
    print(out)
    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            f.write(out)
    return 0 if rec.get("status", "").startswith(("ok", "SKIP")) else 1


def sweep(args) -> int:
    from repro import configs  # control-plane import only (no jax device init)

    os.makedirs(args.out_dir, exist_ok=True)
    meshes = []
    if "single" in args.meshes:
        meshes.append(False)
    if "multi" in args.meshes:
        meshes.append(True)
    failures = []
    for arch in configs.ARCHS:
        public = {v: k for k, v in configs.ALIASES.items()}[arch]
        for cell in configs.SHAPES:
            for mp in meshes:
                tag = f"{arch}__{cell.name}__{'2x16x16' if mp else '16x16'}"
                path = os.path.join(args.out_dir, tag + ".json")
                if os.path.exists(path):
                    rec = json.load(open(path))
                    if rec.get("status", "").startswith(("ok", "SKIP")):
                        print(f"cached  {tag}: {rec['status']}")
                        continue
                cmd = [
                    sys.executable, "-m", "repro.launch.dryrun",
                    "--arch", public, "--shape", cell.name, "--json", path,
                ]
                if mp:
                    cmd.append("--multi-pod")
                print(f"run     {tag} ...", flush=True)
                t0 = time.time()
                proc = subprocess.run(
                    cmd, capture_output=True, text=True, timeout=args.timeout
                )
                dt = time.time() - t0
                if proc.returncode != 0:
                    failures.append(tag)
                    with open(os.path.join(args.out_dir, tag + ".err"), "w") as f:
                        f.write(proc.stdout[-5000:] + "\n" + proc.stderr[-20000:])
                    print(f"FAIL    {tag} ({dt:.0f}s) — see {tag}.err")
                else:
                    rec = json.load(open(path))
                    print(f"ok      {tag} ({dt:.0f}s): {rec['status']}")
    print(f"\nsweep done; {len(failures)} failures: {failures}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
