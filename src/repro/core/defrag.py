"""Defragmentation planning — the paper's §4.3/§7 future work, implemented.

Repeated merge/unmerge leaves a running DAG deployed as many small segments
joined by broker topics, plus paused tasks that still consume ε resources
(the paper measures ≈7.5 cores of pause residue at the end of the OPMW
drain). Defragmentation stops all segments and relaunches **one** segment
per running DAG containing exactly the live tasks — removing every broker
hop and all pause overhead, and handing XLA a single program so cross-
segment fusion/CSE applies.

This module is pure control-plane planning (graph work only); enactment —
state carry-over and recompilation — lives in
:meth:`repro.runtime.system.StreamSystem.defragment`.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List

from .graph import Dataflow
from .signatures import compute_signatures


@dataclass
class FusedDag:
    """One fused segment to launch for a running DAG."""

    dag_name: str
    order: List[str]  # all live tasks, topological
    parents: Dict[str, List[str]]  # canonical (signature-sorted) parent order


@dataclass
class DefragPlan:
    fused: List[FusedDag] = field(default_factory=list)

    @property
    def total_tasks(self) -> int:
        return sum(len(f.order) for f in self.fused)


def canonical_parents(df: Dataflow) -> Dict[str, List[str]]:
    """Parent lists sorted by Merkle signature.

    Equivalent tasks have equal signatures and de-dup DAGs have distinct
    signatures within a parent set, so this order is invariant under the
    equivalence bijection — Default and Reuse runs interleave parent streams
    identically and sink outputs stay bit-identical.
    """
    sigs = compute_signatures(df)
    return {t: sorted(df.parents(t), key=lambda p: sigs[p]) for t in df.tasks}


def plan_defrag(running: Dict[str, Dataflow]) -> DefragPlan:
    """One fused segment per running DAG (live tasks only — the manager has
    already removed terminated tasks from the running DAGs; paused residue
    exists only in the data plane and is dropped on enactment)."""
    plan = DefragPlan()
    for dag_name in sorted(running):
        df = running[dag_name]
        if not df.tasks:
            continue
        plan.fused.append(
            FusedDag(
                dag_name=dag_name,
                order=df.topological_order(),
                parents=canonical_parents(df),
            )
        )
    return plan
