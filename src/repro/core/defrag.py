"""Defragmentation planning — the paper's §4.3/§7 future work, implemented.

Repeated merge/unmerge leaves a running DAG deployed as many small segments
joined by broker topics, plus paused tasks that still consume ε resources
(the paper measures ≈7.5 cores of pause residue at the end of the OPMW
drain). Defragmentation stops all segments and relaunches **one** segment
per running DAG containing exactly the live tasks — removing every broker
hop and all pause overhead, and handing XLA a single program so cross-
segment fusion/CSE applies.

This module is pure control-plane planning (graph work only); enactment —
state carry-over and recompilation — lives in
:meth:`repro.runtime.system.StreamSystem.defragment`.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Set

from .graph import Dataflow
from .signatures import compute_signatures


@dataclass
class FusedDag:
    """One fused segment to launch for a running DAG."""

    dag_name: str
    order: List[str]  # all live tasks, topological
    parents: Dict[str, List[str]]  # canonical (signature-sorted) parent order


@dataclass
class DefragPlan:
    fused: List[FusedDag] = field(default_factory=list)

    @property
    def total_tasks(self) -> int:
        return sum(len(f.order) for f in self.fused)


def canonical_parents(df: Dataflow) -> Dict[str, List[str]]:
    """Parent lists sorted by Merkle signature.

    Equivalent tasks have equal signatures and de-dup DAGs have distinct
    signatures within a parent set, so this order is invariant under the
    equivalence bijection — Default and Reuse runs interleave parent streams
    identically and sink outputs stay bit-identical.
    """
    sigs = compute_signatures(df)
    return {t: sorted(df.parents(t), key=lambda p: sigs[p]) for t in df.tasks}


@dataclass
class FusionChain:
    """A maximal linear run of same-DAG segments to compile into one."""

    dag_name: str
    members: List[str]  # segment names, upstream -> downstream


@dataclass
class FusionPlan:
    chains: List[FusionChain] = field(default_factory=list)

    @property
    def total_segments(self) -> int:
        return sum(len(c.members) for c in self.chains)


def plan_fusion(
    seg_deps: Mapping[str, Set[str]],
    dag_of: Mapping[str, str],
    min_length: int = 2,
) -> FusionPlan:
    """Find maximal linear segment chains worth fusing.

    A pair ``(a, b)`` is a *sole link* when ``b``'s only dependency is
    ``a`` and ``a``'s only dependent is ``b`` — the boundary stream
    between them is a private pipe with no fan-in or fan-out. Fusing
    exactly these chains collapses the pipe into an XLA temporary
    without serialising anything that was running in parallel: wide
    waves stay wide, only depth is fused. Segment dependencies only
    arise from boundary streams *within* one merged running DAG, so a
    chain never spans DAGs; ``dag_of`` labels the chain with its newest
    member's running-DAG name (merges rename the running DAG, so
    members carry different historical names).

    Pure planning (graph work only) like :func:`plan_defrag`; enactment
    lives in :meth:`repro.runtime.system.StreamSystem.fuse`.
    """
    dependents: Dict[str, List[str]] = {name: [] for name in seg_deps}
    for name in sorted(seg_deps):
        for dep in seg_deps[name]:
            if dep in dependents:
                dependents[dep].append(name)

    def sole_link(a: str, b: str) -> bool:
        return set(seg_deps.get(b, ())) == {a} and dependents.get(a) == [b]

    def successor(a: str) -> Optional[str]:
        down = dependents.get(a, [])
        if len(down) == 1 and sole_link(a, down[0]):
            return down[0]
        return None

    plan = FusionPlan()
    for name in sorted(seg_deps):
        # chain heads: extendable forward, not extendable backward
        if successor(name) is None:
            continue
        preds = seg_deps.get(name, set())
        if len(preds) == 1 and sole_link(next(iter(preds)), name):
            continue  # interior node — its head starts the chain
        members = [name]
        nxt = successor(name)
        while nxt is not None:
            members.append(nxt)
            nxt = successor(nxt)
        if len(members) >= min_length:
            plan.chains.append(
                FusionChain(dag_name=dag_of.get(members[-1], ""), members=members)
            )
    return plan


def plan_defrag(running: Dict[str, Dataflow]) -> DefragPlan:
    """One fused segment per running DAG (live tasks only — the manager has
    already removed terminated tasks from the running DAGs; paused residue
    exists only in the data plane and is dropped on enactment)."""
    plan = DefragPlan()
    for dag_name in sorted(running):
        df = running[dag_name]
        if not df.tasks:
            continue
        plan.fused.append(
            FusedDag(
                dag_name=dag_name,
                order=df.topological_order(),
                parents=canonical_parents(df),
            )
        )
    return plan
