"""Defragmentation planning — the paper's §4.3/§7 future work, implemented.

Repeated merge/unmerge leaves a running DAG deployed as many small segments
joined by broker topics, plus paused tasks that still consume ε resources
(the paper measures ≈7.5 cores of pause residue at the end of the OPMW
drain). Defragmentation stops all segments and relaunches **one** segment
per running DAG containing exactly the live tasks — removing every broker
hop and all pause overhead, and handing XLA a single program so cross-
segment fusion/CSE applies.

This module is pure control-plane planning (graph work only); enactment —
state carry-over and recompilation — lives in
:meth:`repro.runtime.system.StreamSystem.defragment`.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Set

from .graph import Dataflow
from .signatures import compute_signatures


@dataclass
class FusedDag:
    """One fused segment to launch for a running DAG."""

    dag_name: str
    order: List[str]  # all live tasks, topological
    parents: Dict[str, List[str]]  # canonical (signature-sorted) parent order


@dataclass
class DefragPlan:
    fused: List[FusedDag] = field(default_factory=list)

    @property
    def total_tasks(self) -> int:
        return sum(len(f.order) for f in self.fused)


def canonical_parents(df: Dataflow) -> Dict[str, List[str]]:
    """Parent lists sorted by Merkle signature.

    Equivalent tasks have equal signatures and de-dup DAGs have distinct
    signatures within a parent set, so this order is invariant under the
    equivalence bijection — Default and Reuse runs interleave parent streams
    identically and sink outputs stay bit-identical.
    """
    sigs = compute_signatures(df)
    return {t: sorted(df.parents(t), key=lambda p: sigs[p]) for t in df.tasks}


@dataclass
class FusionChain:
    """A maximal linear run of same-DAG segments to compile into one."""

    dag_name: str
    members: List[str]  # segment names, upstream -> downstream


@dataclass
class FusionPlan:
    chains: List[FusionChain] = field(default_factory=list)

    @property
    def total_segments(self) -> int:
        return sum(len(c.members) for c in self.chains)


def plan_fusion(
    seg_deps: Mapping[str, Set[str]],
    dag_of: Mapping[str, str],
    min_length: int = 2,
) -> FusionPlan:
    """Find maximal linear segment chains worth fusing.

    A pair ``(a, b)`` is a *sole link* when ``b``'s only dependency is
    ``a`` and ``a``'s only dependent is ``b`` — the boundary stream
    between them is a private pipe with no fan-in or fan-out. Fusing
    exactly these chains collapses the pipe into an XLA temporary
    without serialising anything that was running in parallel: wide
    waves stay wide, only depth is fused. Segment dependencies only
    arise from boundary streams *within* one merged running DAG, so a
    chain never spans DAGs; ``dag_of`` labels the chain with its newest
    member's running-DAG name (merges rename the running DAG, so
    members carry different historical names).

    Pure planning (graph work only) like :func:`plan_defrag`; enactment
    lives in :meth:`repro.runtime.system.StreamSystem.fuse`.

    Only segments present in **both** ``seg_deps`` and ``dag_of`` are
    planned over, and dependency edges onto absent segments are dropped:
    after a fuse/unmerge/defragment cycle either view can briefly hold
    stale names, and a chain must never propose a killed segment. Re-runs
    on an unchanged system are idempotent — a fused chain is a single
    node with no sole link, so it is simply not proposed again.
    """
    nodes = set(seg_deps) & set(dag_of)
    deps = {n: {d for d in seg_deps.get(n, ()) if d in nodes} for n in nodes}
    dependents: Dict[str, List[str]] = {name: [] for name in deps}
    for name in sorted(deps):
        for dep in deps[name]:
            if dep in dependents:
                dependents[dep].append(name)

    def sole_link(a: str, b: str) -> bool:
        return deps.get(b, set()) == {a} and dependents.get(a) == [b]

    def successor(a: str) -> Optional[str]:
        down = dependents.get(a, [])
        if len(down) == 1 and sole_link(a, down[0]):
            return down[0]
        return None

    plan = FusionPlan()
    for name in sorted(deps):
        # chain heads: extendable forward, not extendable backward
        if successor(name) is None:
            continue
        preds = deps.get(name, set())
        if len(preds) == 1 and sole_link(next(iter(preds)), name):
            continue  # interior node — its head starts the chain
        members = [name]
        nxt = successor(name)
        while nxt is not None:
            members.append(nxt)
            nxt = successor(nxt)
        if len(members) >= min_length:
            plan.chains.append(
                FusionChain(dag_name=dag_of.get(members[-1], ""), members=members)
            )
    return plan


# -- wave-aware fusion scoring -------------------------------------------------


@dataclass
class FusionDecision:
    """One accept/reject verdict from :func:`score_fusion_plan`."""

    chain: FusionChain
    accepted: bool
    reason: str
    est_benefit_ms: float = 0.0
    est_penalty_ms: float = 0.0
    target_slot: int = 0
    member_slots: Dict[str, int] = field(default_factory=dict)

    def to_dict(self) -> Dict[str, object]:
        return {
            "dag": self.chain.dag_name,
            "members": list(self.chain.members),
            "accepted": bool(self.accepted),
            "reason": self.reason,
            "est_benefit_ms": round(float(self.est_benefit_ms), 4),
            "est_penalty_ms": round(float(self.est_penalty_ms), 4),
            "target_slot": int(self.target_slot),
            "member_slots": dict(self.member_slots),
        }


@dataclass
class FusionReport:
    """Every candidate chain's verdict — the planner's explanation."""

    decisions: List[FusionDecision] = field(default_factory=list)

    @property
    def accepted(self) -> List[FusionDecision]:
        return [d for d in self.decisions if d.accepted]

    @property
    def rejected(self) -> List[FusionDecision]:
        return [d for d in self.decisions if not d.accepted]

    def to_dict(self) -> Dict[str, object]:
        return {
            "accepted": [d.to_dict() for d in self.accepted],
            "rejected": [d.to_dict() for d in self.rejected],
        }


def score_fusion_plan(
    plan: FusionPlan,
    seg_deps: Mapping[str, Set[str]],
    seg_ms: Mapping[str, float],
    slot_of: Optional[Mapping[str, int]] = None,
    n_slots: int = 1,
    overhead_ms: float = 0.25,
) -> FusionReport:
    """Score each candidate chain against a makespan model; keep wide waves wide.

    Fusing a private-pipe chain never serialises anything *within* the
    chain (it is already a serial path), but cross-worker fusion must
    first **consolidate** the members onto one slot — and piling a chain
    onto an already-loaded slot can stretch the step makespan on an
    otherwise well-balanced pool. The model:

      * ``makespan = max(critical_path, max_slot_load)`` — a step can
        finish no sooner than its longest dependency path and no sooner
        than its busiest slot. The critical path is invariant under chain
        contraction (chains are paths), so only the slot-load term moves.
      * benefit  = ``(len − 1) · overhead_ms`` — each fused boundary
        removes one dispatch + broker hop.
      * penalty  = makespan after moving all members onto the cheapest
        slot minus makespan before.

    A chain is accepted iff ``penalty ≤ benefit``; accepted chains update
    the load picture, so later chains are scored against the pool they
    will actually land on. With one slot (in-process/sharded-as-one) the
    penalty is always 0 and every chain is accepted — consolidation is
    the only modelled risk. ``seg_ms`` comes from the dry-run
    :class:`repro.ops.costs.LatencyModel`, fit from live EWMA latency
    samples when the backend has them, so "cheapest slot" tracks the
    EWMA-cheapest worker. Pure planning: no backend types here.
    """
    ms = {n: max(0.0, float(seg_ms.get(n, 0.0))) for n in seg_deps}
    slots = {n: int(slot_of.get(n, 0)) if slot_of else 0 for n in seg_deps}
    n_slots = max(1, int(n_slots))
    loads = [0.0] * n_slots
    for n, m in ms.items():
        loads[slots[n] % n_slots] += m

    # critical path over the segment dependency DAG, memoized bottom-up
    cp_cache: Dict[str, float] = {}

    def cp(n: str) -> float:
        if n not in cp_cache:
            cp_cache[n] = ms.get(n, 0.0) + max(
                (cp(d) for d in seg_deps.get(n, ()) if d in ms), default=0.0
            )
        return cp_cache[n]

    critical = max((cp(n) for n in ms), default=0.0)

    report = FusionReport()
    for chain in plan.chains:
        k = len(chain.members)
        member_slots = {m: slots.get(m, 0) for m in chain.members}
        chain_ms = sum(ms.get(m, 0.0) for m in chain.members)
        benefit = (k - 1) * float(overhead_ms)
        # load picture with the members lifted out, then dropped on the
        # cheapest slot
        minus = list(loads)
        for m in chain.members:
            minus[slots.get(m, 0) % n_slots] -= ms.get(m, 0.0)
        target = min(range(n_slots), key=lambda i: minus[i])
        after = list(minus)
        after[target] += chain_ms
        penalty = max(critical, max(after)) - max(critical, max(loads))
        accepted = penalty <= benefit + 1e-9
        if accepted:
            loads = after
            for m in chain.members:
                slots[m] = target
            reason = (
                f"fuse {k} segments on slot {target}: saves ~{benefit:.3f} ms "
                f"dispatch overhead, makespan +{max(0.0, penalty):.3f} ms"
            )
        else:
            reason = (
                f"consolidating {k} segments ({chain_ms:.3f} ms) onto slot "
                f"{target} would stretch the step makespan by {penalty:.3f} ms "
                f"(> {benefit:.3f} ms saved) — keeping the wave wide"
            )
        report.decisions.append(
            FusionDecision(
                chain=chain,
                accepted=accepted,
                reason=reason,
                est_benefit_ms=benefit,
                est_penalty_ms=penalty,
                target_slot=target,
                member_slots=member_slots,
            )
        )
    return report


def plan_defrag(running: Dict[str, Dataflow]) -> DefragPlan:
    """One fused segment per running DAG (live tasks only — the manager has
    already removed terminated tasks from the running DAGs; paused residue
    exists only in the data plane and is dropped on enactment)."""
    plan = DefragPlan()
    for dag_name in sorted(running):
        df = running[dag_name]
        if not df.tasks:
            continue
        plan.fused.append(
            FusedDag(
                dag_name=dag_name,
                order=df.topological_order(),
                parents=canonical_parents(df),
            )
        )
    return plan
