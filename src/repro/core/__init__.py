"""The paper's primary contribution — collaborative reuse of streaming
dataflows: graph model (§3.1), equivalence (§3.2), system invariants (§3.3),
merge (§4.1) and unmerge (§4.2) algorithms, and the Reusable Dataflow
Manager (§4.3 control plane). The Storm-analogue data plane lives in
:mod:`repro.runtime`; the beyond-paper Merkle-signature fast path in
:mod:`repro.core.signatures`.
"""
from .equivalence import (
    AncestorGraph,
    EquivalenceChecker,
    ancestor_graph,
    ancestor_graph_set,
    ancestor_intersection,
    dataflows_disjoint,
    dedup,
    find_equivalent_tasks,
    is_dedup,
    maximal,
    maximal_ancestor_intersection,
)
from .graph import (
    SINK_CONFIG,
    SOURCE_CONFIG,
    AbstractTask,
    Dataflow,
    DataflowError,
    Stream,
    Task,
    canonical_config,
    down,
    up,
)
from .invariants import InvariantViolation, check_all, check_minimization, check_sink_coverage
from .manager import RemovalReceipt, ReuseManager, SubmissionReceipt
from .merge import MergePlan, apply_merge, build_plan, find_overlapping, plan_merge
from .signatures import SignatureIndex, compute_signatures, dedup_fast, is_dedup_fast, signature_of
from .strategies import (
    MergeStrategy,
    available_strategies,
    register_strategy,
    resolve_strategy,
)
from .unmerge import UnmergePlan, apply_unmerge, plan_unmerge

__all__ = [
    "AbstractTask",
    "AncestorGraph",
    "Dataflow",
    "DataflowError",
    "EquivalenceChecker",
    "InvariantViolation",
    "MergePlan",
    "MergeStrategy",
    "RemovalReceipt",
    "ReuseManager",
    "SINK_CONFIG",
    "SOURCE_CONFIG",
    "SignatureIndex",
    "Stream",
    "SubmissionReceipt",
    "Task",
    "UnmergePlan",
    "ancestor_graph",
    "ancestor_graph_set",
    "ancestor_intersection",
    "apply_merge",
    "apply_unmerge",
    "available_strategies",
    "build_plan",
    "canonical_config",
    "check_all",
    "check_minimization",
    "check_sink_coverage",
    "compute_signatures",
    "dataflows_disjoint",
    "dedup",
    "dedup_fast",
    "down",
    "find_equivalent_tasks",
    "find_overlapping",
    "is_dedup",
    "is_dedup_fast",
    "maximal",
    "maximal_ancestor_intersection",
    "plan_merge",
    "plan_unmerge",
    "register_strategy",
    "resolve_strategy",
    "signature_of",
    "up",
]
