"""Unmerging algorithm — paper §4.2.

When a submitted dataflow ``D_r`` is removed: find the running DAG that
contains it (Φ), compute the union of ancestor graphs of the sinks of the
*remaining* submitted DAGs it supports (Δ), terminate every running task and
stream outside that union, and split the survivor into weakly connected
components — each becomes its own running DAG (running DAGs must stay
mutually disjoint).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Set

from .equivalence import ancestor_graph
from .graph import Dataflow, Stream


@dataclass
class UnmergePlan:
    removed_name: str
    running_name: str  # Φ(D_r) — the (single) running DAG affected
    terminated_tasks: Set[str] = field(default_factory=set)  # T_t (running ids)
    terminated_streams: Set[Stream] = field(default_factory=set)  # S_t
    # name → task-id set for each connected component that survives
    components: Dict[str, Set[str]] = field(default_factory=dict)


def plan_unmerge(
    running_df: Dataflow,
    remaining_task_maps: Dict[str, Dict[str, str]],
    remaining_sinks: Dict[str, List[str]],
    removed_name: str,
    mint_name: Callable[[], str],
) -> UnmergePlan:
    """Compute the unmerge plan.

    Args:
      running_df: D̄_i = Φ(D_r).
      remaining_task_maps: for each submitted DAG in Δ(D̄_i) \\ {D_r}, its
        submitted-id → running-id map.
      remaining_sinks: for each of those DAGs, its submitted sink ids.
      removed_name: name of D_r.
      mint_name: mints fresh names for the unmerged component DAGs.
    """
    plan = UnmergePlan(removed_name=removed_name, running_name=running_df.name)

    # Union of ancestor graphs of the remaining sinks (𝔸 in the paper).
    retained: Set[str] = set()
    for sub_name, sinks in remaining_sinks.items():
        task_map = remaining_task_maps[sub_name]
        for sink_id in sinks:
            run_sink = task_map[sink_id]
            retained |= ancestor_graph(running_df, run_sink).task_ids

    # T_t — running tasks in no remaining sink's ancestor graph.
    plan.terminated_tasks = set(running_df.tasks) - retained
    # S_t — streams incident on a terminated task.
    plan.terminated_streams = {
        s for s in running_df.streams if s[0] in plan.terminated_tasks or s[1] in plan.terminated_tasks
    }

    # Split the survivor into weakly connected components.
    survivor = running_df.subgraph("__survivor__", retained)
    for comp in survivor.connected_components():
        plan.components[mint_name()] = comp
    return plan


def apply_unmerge(running: Dict[str, Dataflow], plan: UnmergePlan) -> List[Dataflow]:
    """Enact the plan: replace Φ(D_r) with the surviving components."""
    df = running.pop(plan.running_name)
    new_dfs: List[Dataflow] = []
    for name, comp in plan.components.items():
        new_dfs.append(df.subgraph(name, comp))
        new_dfs[-1].name = name
        running[name] = new_dfs[-1]
    return new_dfs
