"""Reusable Dataflow Manager — paper §4.3, control plane.

Maintains the submitted set 𝔻, the running set 𝔻̄, the decomposition map
Δ : 𝔻̄ → P(𝔻) and inverse Φ : 𝔻 → 𝔻̄, the per-submission task maps
(submitted id → running id), and a durable journal of operations for
crash-recovery (replay reconstructs the state byte-identically — the
fault-tolerance story for the control plane).

``strategy`` picks the equivalence engine from the pluggable registry
(:mod:`repro.core.strategies`): ``"signature"`` (Merkle index, beyond-paper
fast path, default), ``"faithful"`` (the paper's bijection check) or
``"none"`` (the Default baseline — no reuse, every submission runs
independently; used for the paper's Default-vs-Reuse comparisons). A
:class:`~repro.core.strategies.MergeStrategy` instance is also accepted.
"""
from __future__ import annotations

import json
import time
from contextlib import nullcontext
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple, Union

from . import invariants
from .equivalence import ancestor_graph
from .graph import Dataflow, DataflowError, Task
from .merge import MergePlan, apply_merge, build_plan
from .signatures import SignatureIndex, compute_signatures
from .strategies import MergeStrategy, resolve_strategy
from .unmerge import UnmergePlan, apply_unmerge, plan_unmerge


@dataclass
class SubmissionReceipt:
    """Returned to the user on submit — where their outputs land (§4.1)."""

    name: str
    running_dag: str
    sink_map: Dict[str, str]  # submitted sink id → running task id
    num_reused: int
    num_created: int
    plan: MergePlan


@dataclass
class RemovalReceipt:
    name: str
    terminated_tasks: Set[str]
    surviving_dags: List[str]
    plan: UnmergePlan


class ReuseManager:
    def __init__(
        self,
        strategy: Union[str, MergeStrategy] = "signature",
        check_invariants: bool = False,
        journal_path: Optional[str] = None,
    ):
        self._strategy = resolve_strategy(strategy)
        self.strategy = self._strategy.name  # back-compat string view
        self.check_invariants = check_invariants
        self.journal_path = journal_path

        self.submitted: Dict[str, Dataflow] = {}
        self.running: Dict[str, Dataflow] = {}
        self.task_maps: Dict[str, Dict[str, str]] = {}  # sub name → (sub id → run id)
        self.phi: Dict[str, str] = {}  # Φ : submitted → running
        self.delta: Dict[str, Set[str]] = {}  # Δ : running → submitted set
        self.index = SignatureIndex()
        self._task_counter = 0
        self._dag_counter = 0
        self.journal: List[Dict[str, Any]] = []
        # -- telemetry plane (repro.obs, optional) ---------------------------
        # An owning StreamSystem wires its backend's Tracer in here so
        # merge/unmerge/preview planning shows up as "control" spans; the
        # cumulative op counters below are mirrored into the metrics
        # registry by a snapshot-time collector (never read on the hot
        # path). Journal replay re-runs submit/remove, so a restored
        # manager's counters are consistent with its rebuilt Δ/Φ state.
        self.tracer: Optional[Any] = None
        self.op_counts: Dict[str, int] = {
            "tasks_submitted": 0,  # running tasks requested (reused + created)
            "tasks_reused": 0,  # requested tasks satisfied by a running task
            "tasks_created": 0,  # requested tasks that had to be instantiated
            "merge_events": 0,  # submissions that reused ≥1 running task
            "unmerge_events": 0,  # removals (every removal plans an unmerge)
            "previews": 0,  # admission-control dry plans
        }

    def _span(self, name: str, **args: Any):
        """A "control"-category tracer span, or a no-op without a tracer."""
        tracer = self.tracer
        if tracer is not None and tracer.enabled:
            return tracer.span(name, "control", **args)
        return nullcontext()

    def _count_merge(self, plan: MergePlan) -> None:
        oc = self.op_counts
        oc["tasks_submitted"] += plan.num_reused + plan.num_created
        oc["tasks_reused"] += plan.num_reused
        oc["tasks_created"] += plan.num_created
        if plan.num_reused:
            oc["merge_events"] += 1

    # -- id minting ----------------------------------------------------------
    def _mint_task_id(self, type_hint: str = "t") -> str:
        self._task_counter += 1
        return f"r{self._task_counter}.{type_hint[:16]}"

    def _mint_dag_name(self) -> str:
        self._dag_counter += 1
        return f"run{self._dag_counter}"

    # -- validation ----------------------------------------------------------
    def _validate_submission(self, df: Dataflow) -> Dict[str, str]:
        """Structural + de-dup validation; returns the signature map (one pass)."""
        df.validate()
        for tid in df.tasks:
            t = df.tasks[tid]
            if not t.is_sink and not df.children(tid):
                raise DataflowError(
                    f"task {tid!r} is a non-sink leaf; submitted DAGs must "
                    f"terminate in sink tasks (paper §3.3 C2)"
                )
        sigs = compute_signatures(df)
        if len(set(sigs.values())) != len(sigs):
            raise DataflowError(f"submitted dataflow {df.name!r} is not de-dup (§3.2)")
        return sigs

    # -- operations ------------------------------------------------------------
    def submit(self, df: Dataflow, validate: bool = True) -> SubmissionReceipt:
        """Merge a submitted de-dup DAG into the running set (paper §4.1)."""
        if df.name in self.submitted:
            raise DataflowError(f"dataflow {df.name!r} already submitted")
        sigs: Optional[Dict[str, str]] = None
        if validate:
            sigs = self._validate_submission(df)
        elif self._strategy.wants_signatures:
            sigs = compute_signatures(df)

        df = df.copy()  # signatures are keyed by task id, which copy preserves
        merged_name = self._mint_dag_name()
        with self._span("merge", dataflow=df.name, running_dag=merged_name):
            plan = self._strategy.plan(self, df, merged_name, sigs=sigs)
            # Update Δ/Φ: all submissions supported by the absorbed DAGs now
            # map to the merged DAG.
            absorbed: Set[str] = set()
            for run_name in plan.overlapping:
                absorbed |= self.delta.pop(run_name, set())
            apply_merge(self.running, df, plan)
        for sub_name in absorbed:
            self.phi[sub_name] = merged_name
        self.submitted[df.name] = df
        self.task_maps[df.name] = plan.task_map
        self.phi[df.name] = merged_name
        self.delta[merged_name] = absorbed | {df.name}
        self._strategy.on_merged(self, df, plan, sigs=sigs)

        self._journal({"op": "submit", "dataflow": df.to_json()})
        self._count_merge(plan)
        receipt = SubmissionReceipt(
            name=df.name,
            running_dag=merged_name,
            sink_map={s: plan.task_map[s] for s in df.sink_ids},
            num_reused=plan.num_reused,
            num_created=plan.num_created,
            plan=plan,
        )
        if self.check_invariants:
            self.verify()
        return receipt

    def preview(self, df: Dataflow, validate: bool = True) -> MergePlan:
        """Plan the merge for ``df`` WITHOUT committing it.

        Runs the strategy's matching against the current running set and
        returns the resulting :class:`~repro.core.merge.MergePlan` —
        ``plan.num_created`` is the number of new running tasks the
        submission would instantiate, which is what admission control
        charges against a slot pool (a fully-reused submission costs 0).

        The manager is left bit-identical: the plan mints placeholder ids
        through the task counter, which is restored afterwards, so a
        preview followed by the real :meth:`submit` produces exactly the
        ids (and journal) an un-previewed submit would have. No journal
        entry is written. ``validate=False`` skips the structural de-dup
        check for trusted callers on a hot admission path.
        """
        if df.name in self.submitted:
            raise DataflowError(f"dataflow {df.name!r} already submitted")
        sigs: Optional[Dict[str, str]] = None
        if validate:
            sigs = self._validate_submission(df)
        elif self._strategy.wants_signatures:
            sigs = compute_signatures(df)
        saved_counter = self._task_counter
        self.op_counts["previews"] += 1
        try:
            with self._span("preview", dataflow=df.name):
                return self._strategy.plan(self, df, "__preview__", sigs=sigs)
        finally:
            self._task_counter = saved_counter

    def submit_many(
        self, dfs: Sequence[Dataflow], validate: bool = True
    ) -> List[SubmissionReceipt]:
        """Submit a batch with batch-aware planning (beyond-paper).

        Under heavy multi-tenant arrival rates, N overlapping submissions
        paid N independent merges: each submit re-hashed its DAG up to three
        times (de-dup check, matching, index maintenance) and rebuilt the
        growing merged running DAG from scratch. The batch planner

          1. computes each DAG's Merkle signatures exactly once and shares
             them across validation, matching and index maintenance;
          2. groups the batch with the running set by source-type
             connectivity (union-find), plans every member against the
             running set *plus the batch tasks planned so far* — so
             cross-submission overlap inside the batch is de-duplicated
             before anything touches the running set; and
          3. rebuilds each group's merged running DAG once, not once per
             member.

        The result is state-identical to sequential :meth:`submit` calls
        (same running task ids and DAG names, same Δ/Φ, same journal entries
        in the same order — the journal still holds one ``submit`` op per
        member, so replay needs no new op type). Receipts differ from
        sequential in one deliberate way: every member's receipt (and its
        ``plan.merged_name``) names the group's *final* merged DAG — the
        one actually present in the running set — rather than an
        intermediate name a later member immediately absorbed.
        Strategies without ``supports_batch`` fall back to sequential;
        batch-capable strategies supply the matching via
        :meth:`~repro.core.strategies.MergeStrategy.batch_match`.
        """
        dfs = list(dfs)
        if not dfs:
            return []
        names_seen: Set[str] = set()
        for df in dfs:
            if df.name in self.submitted or df.name in names_seen:
                raise DataflowError(f"dataflow {df.name!r} already submitted")
            names_seen.add(df.name)
        if not self._strategy.supports_batch or len(dfs) == 1:
            return [self.submit(df, validate=validate) for df in dfs]

        # One signature pass per member, shared with validation.
        sigs_of: Dict[str, Dict[str, str]] = {}
        copies: List[Dataflow] = []
        for df in dfs:
            sigs_of[df.name] = (
                self._validate_submission(df) if validate else compute_signatures(df)
            )
            copies.append(df.copy())

        # Group records; planning then walks members in BATCH order so dag
        # names and task ids mint exactly as sequential submits would.
        records: List[Dict[str, Any]] = []
        record_of: Dict[str, Dict[str, Any]] = {}
        for members, run_names in self._group_by_sources(copies):
            overlap_tasks: Set[str] = set()
            for rn in run_names:
                overlap_tasks |= set(self.running[rn].tasks)
            rec: Dict[str, Any] = {
                "members": [],
                "plans": [],
                "run_names": run_names,
                "overlap_tasks": overlap_tasks,
                "created_by_sig": {},
                "merged_name": "",
                "last_idx": -1,
            }
            records.append(rec)
            for df in members:
                record_of[df.name] = rec

        for idx, df in enumerate(copies):
            rec = record_of[df.name]
            merged_name = self._mint_dag_name()  # the group keeps the last name
            sigs = sigs_of[df.name]
            matches = self._strategy.batch_match(
                self, df, sigs, rec["overlap_tasks"], rec["created_by_sig"]
            )
            plan = build_plan(df, matches, rec["run_names"], self._mint_task_id, merged_name)
            for tid, rid in plan.created.items():
                rec["created_by_sig"][sigs[tid]] = rid
            rec["members"].append(df)
            rec["plans"].append(plan)
            rec["merged_name"] = merged_name
            rec["last_idx"] = idx

        # Apply each group once, in the order sequential submits would have
        # last touched them (preserves the running set's insertion order).
        for rec in sorted(records, key=lambda r: r["last_idx"]):
            self._apply_group(rec, sigs_of)

        # Journal + receipts in batch order, mirroring sequential submits.
        receipts: List[SubmissionReceipt] = []
        for df in copies:
            plan = record_of[df.name]["plans"][record_of[df.name]["members"].index(df)]
            self._journal({"op": "submit", "dataflow": df.to_json()})
            self._count_merge(plan)
            receipts.append(
                SubmissionReceipt(
                    name=df.name,
                    running_dag=plan.merged_name,
                    sink_map={s: plan.task_map[s] for s in df.sink_ids},
                    num_reused=plan.num_reused,
                    num_created=plan.num_created,
                    plan=plan,
                )
            )
        if self.check_invariants:
            self.verify()
        return receipts

    def _group_by_sources(
        self, dfs: List[Dataflow]
    ) -> List[Tuple[List[Dataflow], List[str]]]:
        """Partition batch members + running DAGs into connected groups.

        Two dataflows land in the same group iff they are transitively
        connected through shared source types — exactly the closure that
        sequential merging would produce (paper §4.1 source pruning).
        Returns ``(members, overlapping_running_names)`` per group, members
        in batch order.
        """
        parent: Dict[Any, Any] = {}

        def find(x: Any) -> Any:
            parent.setdefault(x, x)
            while parent[x] != x:
                parent[x] = parent[parent[x]]
                x = parent[x]
            return x

        def union(a: Any, b: Any) -> None:
            ra, rb = find(a), find(b)
            if ra != rb:
                parent[ra] = rb

        for df in dfs:
            for st in df.source_types:
                union(("df", df.name), ("src", st))
        for run_name, run_df in self.running.items():
            for st in run_df.source_types:
                union(("run", run_name), ("src", st))

        members: Dict[Any, List[Dataflow]] = {}
        for df in dfs:
            members.setdefault(find(("df", df.name)), []).append(df)
        groups: List[Tuple[List[Dataflow], List[str]]] = []
        for root, group_dfs in members.items():
            run_names = [rn for rn in self.running if find(("run", rn)) == root]
            groups.append((group_dfs, run_names))
        return groups

    def _apply_group(self, rec: Dict[str, Any], sigs_of: Dict[str, Dict[str, str]]) -> None:
        """Enact one connected group of a batch in a single merged-DAG rebuild."""
        members: List[Dataflow] = rec["members"]
        plans: List[MergePlan] = rec["plans"]
        run_names: List[str] = rec["run_names"]
        merged_name: str = rec["merged_name"]
        # Every member's plan reports the group's final DAG — intermediate
        # minted names never materialize in the running set.
        for plan in plans:
            plan.merged_name = merged_name

        merged = Dataflow(merged_name)
        for rn in run_names:
            for t in self.running[rn].tasks.values():
                merged.add_task(t)
            for s in self.running[rn].streams:
                merged.add_stream(*s)
        for df, plan in zip(members, plans):
            for sub_id, run_id in plan.created.items():
                t = df.tasks[sub_id]
                merged.add_task(Task(id=run_id, type=t.type, config=t.config))
            for s in plan.new_streams_internal:
                merged.add_stream(*s)
            for s in plan.new_streams_boundary:
                merged.add_stream(*s)

        absorbed: Set[str] = set()
        for rn in run_names:
            absorbed |= self.delta.pop(rn, set())
            del self.running[rn]
        self.running[merged_name] = merged
        for sub_name in absorbed:
            self.phi[sub_name] = merged_name
        self.delta[merged_name] = set(absorbed)

        for df, plan in zip(members, plans):
            self.submitted[df.name] = df
            self.task_maps[df.name] = plan.task_map
            self.phi[df.name] = merged_name
            self.delta[merged_name].add(df.name)
            self._strategy.on_merged(self, df, plan, sigs=sigs_of[df.name])

    def remove(self, name: str) -> RemovalReceipt:
        """Remove a submitted DAG and unmerge the running set (paper §4.2)."""
        if name not in self.submitted:
            raise DataflowError(f"dataflow {name!r} was not submitted")
        run_name = self.phi[name]
        run_df = self.running[run_name]
        remaining = sorted(self.delta[run_name] - {name})
        with self._span("unmerge", dataflow=name, running_dag=run_name):
            plan = plan_unmerge(
                run_df,
                remaining_task_maps={n: self.task_maps[n] for n in remaining},
                remaining_sinks={n: self.submitted[n].sink_ids for n in remaining},
                removed_name=name,
                mint_name=self._mint_dag_name,
            )
            apply_unmerge(self.running, plan)
        # Re-point Δ/Φ for the survivors: a submitted DAG belongs to the
        # component that contains its mapped tasks (exactly one, verified).
        del self.delta[run_name]
        for comp_name in plan.components:
            self.delta[comp_name] = set()
        for sub_name in remaining:
            mapped = set(self.task_maps[sub_name].values())
            homes = [cn for cn, comp in plan.components.items() if mapped & comp]
            if len(homes) != 1 or not mapped <= plan.components[homes[0]]:
                raise AssertionError(
                    f"unmerge split submitted DAG {sub_name!r} across components"
                )
            self.phi[sub_name] = homes[0]
            self.delta[homes[0]].add(sub_name)
        # Drop empty components (cannot happen if remaining non-empty; if no
        # remaining submissions, everything was terminated).
        for comp_name in [c for c, subs in self.delta.items() if not subs and c in plan.components]:
            if not self.running[comp_name].tasks:
                del self.running[comp_name]
                del self.delta[comp_name]

        del self.submitted[name]
        del self.task_maps[name]
        del self.phi[name]
        self._strategy.on_unmerged(self, plan.terminated_tasks)

        self._journal({"op": "remove", "name": name})
        self.op_counts["unmerge_events"] += 1
        receipt = RemovalReceipt(
            name=name,
            terminated_tasks=set(plan.terminated_tasks),
            surviving_dags=list(plan.components),
            plan=plan,
        )
        if self.check_invariants:
            self.verify()
        return receipt

    # -- introspection / stats -------------------------------------------------
    def verify(self) -> None:
        invariants.check_all(self.submitted, self.running, self.task_maps, self.phi)

    @property
    def running_task_count(self) -> int:
        """The paper's primary metric (Fig. 2)."""
        return sum(len(df.tasks) for df in self.running.values())

    @property
    def submitted_task_count(self) -> int:
        return sum(len(df.tasks) for df in self.submitted.values())

    def reuse_counts(self) -> Dict[str, int]:
        """For each running task, how many submitted DAGs use it (Fig. 4)."""
        counts: Dict[str, int] = {
            tid: 0 for df in self.running.values() for tid in df.tasks
        }
        for sub_name, sub_df in self.submitted.items():
            run_df = self.running[self.phi[sub_name]]
            used: Set[str] = set()
            for sink_id in sub_df.sink_ids:
                used |= ancestor_graph(run_df, self.task_maps[sub_name][sink_id]).task_ids
            for tid in used:
                counts[tid] += 1
        return counts

    # -- durability (control-plane fault tolerance) -----------------------------
    def _journal(self, entry: Dict[str, Any]) -> None:
        entry = dict(entry, ts=time.time())
        self.journal.append(entry)
        if self.journal_path:
            with open(self.journal_path, "a") as f:
                f.write(json.dumps(entry) + "\n")

    def snapshot(self) -> Dict[str, Any]:
        return {
            "strategy": self.strategy,
            "journal": self.journal,
        }

    @classmethod
    def replay(
        cls, journal: List[Dict[str, Any]], strategy: Optional[str] = None, **kwargs: Any
    ) -> "ReuseManager":
        """Rebuild manager state by re-running the operation journal.

        Durable journaling is suspended during the replay itself — otherwise
        a ``journal_path`` pointing at the source file would re-append every
        replayed op, duplicating the journal on each restore. The path is
        re-armed afterwards so *subsequent* operations keep journaling.
        """
        journal_path = kwargs.pop("journal_path", None)
        mgr = cls(strategy=strategy or "signature", **kwargs)
        for entry in journal:
            if entry["op"] == "submit":
                mgr.submit(Dataflow.from_json(entry["dataflow"]))
            elif entry["op"] == "remove":
                mgr.remove(entry["name"])
            else:
                raise ValueError(f"unknown journal op {entry['op']!r}")
        # Keep the original entries (timestamps included), not the re-journaled
        # copies, so a restored manager's journal matches the source.
        mgr.journal = [dict(e) for e in journal]
        mgr.journal_path = journal_path
        return mgr

    @classmethod
    def restore(cls, journal_path: str, **kwargs: Any) -> "ReuseManager":
        journal: List[Dict[str, Any]] = []
        with open(journal_path) as f:
            for line in f:
                line = line.strip()
                if line:
                    journal.append(json.loads(line))
        kwargs.setdefault("journal_path", journal_path)
        return cls.replay(journal, **kwargs)
