"""Reusable Dataflow Manager — paper §4.3, control plane.

Maintains the submitted set 𝔻, the running set 𝔻̄, the decomposition map
Δ : 𝔻̄ → P(𝔻) and inverse Φ : 𝔻 → 𝔻̄, the per-submission task maps
(submitted id → running id), and a durable journal of operations for
crash-recovery (replay reconstructs the state byte-identically — the
fault-tolerance story for the control plane).

``strategy`` picks the equivalence engine: ``"signature"`` (Merkle index,
beyond-paper fast path, default), ``"faithful"`` (the paper's bijection
check) or ``"none"`` (the Default baseline — no reuse, every submission
runs independently; used for the paper's Default-vs-Reuse comparisons).
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Set, Tuple

from . import invariants
from .equivalence import ancestor_graph, is_dedup
from .graph import Dataflow, DataflowError, Task
from .merge import MergePlan, apply_merge, plan_merge
from .signatures import SignatureIndex, compute_signatures, is_dedup_fast
from .unmerge import UnmergePlan, apply_unmerge, plan_unmerge


@dataclass
class SubmissionReceipt:
    """Returned to the user on submit — where their outputs land (§4.1)."""

    name: str
    running_dag: str
    sink_map: Dict[str, str]  # submitted sink id → running task id
    num_reused: int
    num_created: int
    plan: MergePlan


@dataclass
class RemovalReceipt:
    name: str
    terminated_tasks: Set[str]
    surviving_dags: List[str]
    plan: UnmergePlan


class ReuseManager:
    def __init__(
        self,
        strategy: str = "signature",
        check_invariants: bool = False,
        journal_path: Optional[str] = None,
    ):
        if strategy not in ("signature", "faithful", "none"):
            raise ValueError(f"unknown strategy {strategy!r}")
        self.strategy = strategy
        self.check_invariants = check_invariants
        self.journal_path = journal_path

        self.submitted: Dict[str, Dataflow] = {}
        self.running: Dict[str, Dataflow] = {}
        self.task_maps: Dict[str, Dict[str, str]] = {}  # sub name → (sub id → run id)
        self.phi: Dict[str, str] = {}  # Φ : submitted → running
        self.delta: Dict[str, Set[str]] = {}  # Δ : running → submitted set
        self.index = SignatureIndex()
        self._task_counter = 0
        self._dag_counter = 0
        self.journal: List[Dict[str, Any]] = []

    # -- id minting ----------------------------------------------------------
    def _mint_task_id(self, type_hint: str = "t") -> str:
        self._task_counter += 1
        return f"r{self._task_counter}.{type_hint[:16]}"

    def _mint_dag_name(self) -> str:
        self._dag_counter += 1
        return f"run{self._dag_counter}"

    # -- operations ------------------------------------------------------------
    def submit(self, df: Dataflow, validate: bool = True) -> SubmissionReceipt:
        """Merge a submitted de-dup DAG into the running set (paper §4.1)."""
        if df.name in self.submitted:
            raise DataflowError(f"dataflow {df.name!r} already submitted")
        if validate:
            df.validate()
            for tid in df.tasks:
                t = df.tasks[tid]
                if not t.is_sink and not df.children(tid):
                    raise DataflowError(
                        f"task {tid!r} is a non-sink leaf; submitted DAGs must "
                        f"terminate in sink tasks (paper §3.3 C2)"
                    )
            if not is_dedup_fast(df):
                raise DataflowError(f"submitted dataflow {df.name!r} is not de-dup (§3.2)")

        df = df.copy()
        merged_name = self._mint_dag_name()
        if self.strategy == "none":
            plan = self._plan_no_reuse(df, merged_name)
        else:
            plan = plan_merge(
                self.running,
                df,
                mint_id=self._mint_task_id,
                merged_name=merged_name,
                strategy=self.strategy,
                index=self.index if self.strategy == "signature" else None,
            )
        # Update Δ/Φ: all submissions supported by the absorbed DAGs now map
        # to the merged DAG.
        absorbed: Set[str] = set()
        for run_name in plan.overlapping:
            absorbed |= self.delta.pop(run_name, set())
        apply_merge(self.running, df, plan)
        for sub_name in absorbed:
            self.phi[sub_name] = merged_name
        self.submitted[df.name] = df
        self.task_maps[df.name] = plan.task_map
        self.phi[df.name] = merged_name
        self.delta[merged_name] = absorbed | {df.name}
        # Index maintenance: a created running task is equivalent to its
        # submitted counterpart, so it inherits that signature.
        if self.strategy == "signature":
            sigs = compute_signatures(df)
            for sub_id, run_id in plan.created.items():
                self.index.add(run_id, sigs[sub_id])

        self._journal({"op": "submit", "dataflow": df.to_json()})
        receipt = SubmissionReceipt(
            name=df.name,
            running_dag=merged_name,
            sink_map={s: plan.task_map[s] for s in df.sink_ids},
            num_reused=plan.num_reused,
            num_created=plan.num_created,
            plan=plan,
        )
        if self.check_invariants:
            self.verify()
        return receipt

    def _plan_no_reuse(self, df: Dataflow, merged_name: str) -> MergePlan:
        """Default baseline: instantiate everything afresh, merge nothing."""
        plan = MergePlan(submitted_name=df.name, merged_name=merged_name, overlapping=[])
        for tid in df.topological_order():
            plan.created[tid] = self._mint_task_id(df.tasks[tid].type)
        for s_up, s_down in df.streams:
            plan.new_streams_internal.append((plan.created[s_up], plan.created[s_down]))
        return plan

    def remove(self, name: str) -> RemovalReceipt:
        """Remove a submitted DAG and unmerge the running set (paper §4.2)."""
        if name not in self.submitted:
            raise DataflowError(f"dataflow {name!r} was not submitted")
        run_name = self.phi[name]
        run_df = self.running[run_name]
        remaining = sorted(self.delta[run_name] - {name})
        plan = plan_unmerge(
            run_df,
            remaining_task_maps={n: self.task_maps[n] for n in remaining},
            remaining_sinks={n: self.submitted[n].sink_ids for n in remaining},
            removed_name=name,
            mint_name=self._mint_dag_name,
        )
        apply_unmerge(self.running, plan)
        # Re-point Δ/Φ for the survivors: a submitted DAG belongs to the
        # component that contains its mapped tasks (exactly one, verified).
        del self.delta[run_name]
        for comp_name in plan.components:
            self.delta[comp_name] = set()
        for sub_name in remaining:
            mapped = set(self.task_maps[sub_name].values())
            homes = [cn for cn, comp in plan.components.items() if mapped & comp]
            if len(homes) != 1 or not mapped <= plan.components[homes[0]]:
                raise AssertionError(
                    f"unmerge split submitted DAG {sub_name!r} across components"
                )
            self.phi[sub_name] = homes[0]
            self.delta[homes[0]].add(sub_name)
        # Drop empty components (cannot happen if remaining non-empty; if no
        # remaining submissions, everything was terminated).
        for comp_name in [c for c, subs in self.delta.items() if not subs and c in plan.components]:
            if not self.running[comp_name].tasks:
                del self.running[comp_name]
                del self.delta[comp_name]

        del self.submitted[name]
        del self.task_maps[name]
        del self.phi[name]
        if self.strategy == "signature":
            self.index.remove_tasks(plan.terminated_tasks)

        self._journal({"op": "remove", "name": name})
        receipt = RemovalReceipt(
            name=name,
            terminated_tasks=set(plan.terminated_tasks),
            surviving_dags=list(plan.components),
            plan=plan,
        )
        if self.check_invariants:
            self.verify()
        return receipt

    # -- introspection / stats -------------------------------------------------
    def verify(self) -> None:
        invariants.check_all(self.submitted, self.running, self.task_maps, self.phi)

    @property
    def running_task_count(self) -> int:
        """The paper's primary metric (Fig. 2)."""
        return sum(len(df.tasks) for df in self.running.values())

    @property
    def submitted_task_count(self) -> int:
        return sum(len(df.tasks) for df in self.submitted.values())

    def reuse_counts(self) -> Dict[str, int]:
        """For each running task, how many submitted DAGs use it (Fig. 4)."""
        counts: Dict[str, int] = {
            tid: 0 for df in self.running.values() for tid in df.tasks
        }
        for sub_name, sub_df in self.submitted.items():
            run_df = self.running[self.phi[sub_name]]
            used: Set[str] = set()
            for sink_id in sub_df.sink_ids:
                used |= ancestor_graph(run_df, self.task_maps[sub_name][sink_id]).task_ids
            for tid in used:
                counts[tid] += 1
        return counts

    # -- durability (control-plane fault tolerance) -----------------------------
    def _journal(self, entry: Dict[str, Any]) -> None:
        entry = dict(entry, ts=time.time())
        self.journal.append(entry)
        if self.journal_path:
            with open(self.journal_path, "a") as f:
                f.write(json.dumps(entry) + "\n")

    def snapshot(self) -> Dict[str, Any]:
        return {
            "strategy": self.strategy,
            "journal": self.journal,
        }

    @classmethod
    def replay(
        cls, journal: List[Dict[str, Any]], strategy: Optional[str] = None, **kwargs: Any
    ) -> "ReuseManager":
        """Rebuild manager state by re-running the operation journal."""
        mgr = cls(strategy=strategy or "signature", **kwargs)
        for entry in journal:
            if entry["op"] == "submit":
                mgr.submit(Dataflow.from_json(entry["dataflow"]))
            elif entry["op"] == "remove":
                mgr.remove(entry["name"])
            else:
                raise ValueError(f"unknown journal op {entry['op']!r}")
        return mgr

    @classmethod
    def restore(cls, journal_path: str, **kwargs: Any) -> "ReuseManager":
        journal: List[Dict[str, Any]] = []
        with open(journal_path) as f:
            for line in f:
                line = line.strip()
                if line:
                    journal.append(json.loads(line))
        return cls.replay(journal, **kwargs)
