"""System invariants C1 + C2 — paper §3.3.

These checkers are the executable form of the paper's two constraints and
are run by the property-based test suite after arbitrary submit/remove
sequences, and optionally (``ReuseManager(check_invariants=True)``) after
every operation.
"""
from __future__ import annotations

from typing import Dict, List, Set

from .equivalence import EquivalenceChecker, ancestor_graph, dataflows_disjoint, is_dedup
from .graph import Dataflow


class InvariantViolation(AssertionError):
    pass


def check_sink_coverage(
    submitted: Dict[str, Dataflow],
    running: Dict[str, Dataflow],
    task_maps: Dict[str, Dict[str, str]],
    phi: Dict[str, str],
) -> None:
    """C1: ∀ sink t_p in submitted DAGs ∃ running t_q with t_p ↔ t_q (eq. 1).

    We verify the *witness* the manager maintains: the mapped running task
    must exist and be ancestor-equivalent to the submitted sink.
    """
    for sub_name, sub_df in submitted.items():
        run_name = phi.get(sub_name)
        if run_name is None or run_name not in running:
            raise InvariantViolation(f"C1: submitted {sub_name!r} has no running DAG (Φ)")
        run_df = running[run_name]
        task_map = task_maps[sub_name]
        checker = EquivalenceChecker(sub_df, run_df)
        for sink_id in sub_df.sink_ids:
            run_id = task_map.get(sink_id)
            if run_id is None or run_id not in run_df.tasks:
                raise InvariantViolation(
                    f"C1: sink {sink_id!r} of {sub_name!r} not mapped into {run_name!r}"
                )
            if not checker.equivalent(sink_id, run_id):
                raise InvariantViolation(
                    f"C1: sink {sink_id!r} of {sub_name!r} not equivalent to running {run_id!r}"
                )


def check_minimization(
    submitted: Dict[str, Dataflow],
    running: Dict[str, Dataflow],
    task_maps: Dict[str, Dict[str, str]],
    phi: Dict[str, str],
) -> None:
    """C2: running DAGs are disjoint de-dup DAGs and every running task and
    stream lies in some submitted sink's ancestor graph (eq. 2)."""
    names = list(running)
    for i, a in enumerate(names):
        if not is_dedup(running[a]):
            raise InvariantViolation(f"C2: running DAG {a!r} is not de-dup")
        for b in names[i + 1 :]:
            if not dataflows_disjoint(running[a], running[b]):
                raise InvariantViolation(f"C2: running DAGs {a!r}, {b!r} are not disjoint")

    # Coverage of running tasks/streams by submitted sinks' ancestor graphs.
    covered_tasks: Dict[str, Set[str]] = {name: set() for name in running}
    covered_streams: Dict[str, Set] = {name: set() for name in running}
    for sub_name, sub_df in submitted.items():
        run_name = phi[sub_name]
        run_df = running[run_name]
        task_map = task_maps[sub_name]
        for sink_id in sub_df.sink_ids:
            ag = ancestor_graph(run_df, task_map[sink_id])
            covered_tasks[run_name] |= ag.task_ids
            covered_streams[run_name] |= set(ag.streams)
    for name, df in running.items():
        extra_tasks = set(df.tasks) - covered_tasks[name]
        if extra_tasks:
            raise InvariantViolation(
                f"C2: running DAG {name!r} has {len(extra_tasks)} task(s) not in any "
                f"submitted sink's ancestor graph: {sorted(extra_tasks)[:5]}"
            )
        extra_streams = df.streams - covered_streams[name]
        if extra_streams:
            raise InvariantViolation(
                f"C2: running DAG {name!r} has {len(extra_streams)} uncovered stream(s)"
            )


def check_all(
    submitted: Dict[str, Dataflow],
    running: Dict[str, Dataflow],
    task_maps: Dict[str, Dict[str, str]],
    phi: Dict[str, str],
) -> None:
    check_sink_coverage(submitted, running, task_maps, phi)
    check_minimization(submitted, running, task_maps, phi)
