"""Merkle ancestor signatures — beyond-paper O(V+E) equivalence fast path.

The paper decides task equivalence by constructing a bijection between
ancestor graphs (see :mod:`repro.core.equivalence`). That is quadratic in
the number of task pairs. We observe that for de-dup DAGs equivalence admits
a *canonical form*:

    sig(t) = H(type ‖ config ‖ sorted-multiset{ sig(p) : p ∈ π(t) })

**Theorem** (tested by property tests against the faithful checker): for
tasks in de-dup DAGs, ``sig(t_i) == sig(t_j)``  ⟺  ``t_i ↔ t_j`` (up to
SHA-256 collisions). Sketch: ⇐ follows by induction on the bijection ε;
⇒ by induction on DAG depth — equal digests force equal ⟨type, config⟩ and
equal parent-signature multisets, and de-dup means signatures within one
parent set are distinct, so the multiset match induces a unique bijection.

This turns merge from O(|T_n|·|T̄|·depth) into O(V+E) hashing plus O(1)
dict lookups against a signature index of the running tasks.
"""
from __future__ import annotations

import hashlib
from typing import Dict, Iterable, List, Optional, Set

from .graph import Dataflow, Task


def _digest(parts: Iterable[bytes]) -> str:
    h = hashlib.sha256()
    for p in parts:
        h.update(len(p).to_bytes(4, "little"))
        h.update(p)
    return h.hexdigest()


def compute_signatures(df: Dataflow) -> Dict[str, str]:
    """sig(t) for every task in topological order — O(V + E log E)."""
    sigs: Dict[str, str] = {}
    for tid in df.topological_order():
        t = df.tasks[tid]
        parent_sigs = sorted(sigs[p] for p in df.parents(tid))
        sigs[tid] = _digest(
            [t.type.encode(), t.config.encode()] + [s.encode() for s in parent_sigs]
        )
    return sigs


def signature_of(df: Dataflow, task_id: str) -> str:
    """Signature of one task (computes the ancestor closure only)."""
    # Restrict to the ancestor set for efficiency.
    needed: Set[str] = set()
    stack = [task_id]
    while stack:
        tid = stack.pop()
        if tid in needed:
            continue
        needed.add(tid)
        stack.extend(df.parents(tid))
    sigs: Dict[str, str] = {}
    for tid in df.topological_order():
        if tid not in needed:
            continue
        t = df.tasks[tid]
        parent_sigs = sorted(sigs[p] for p in df.parents(tid))
        sigs[tid] = _digest(
            [t.type.encode(), t.config.encode()] + [s.encode() for s in parent_sigs]
        )
    return sigs[task_id]


class SignatureIndex:
    """Incremental index ``sig → running task id`` over the running set.

    The manager keeps one global index (running DAGs are mutually disjoint,
    so signatures never collide across running DAGs for non-equivalent
    tasks; equivalent tasks across running DAGs would violate disjointness).
    """

    def __init__(self) -> None:
        self._by_sig: Dict[str, str] = {}
        self._by_task: Dict[str, str] = {}

    def __len__(self) -> int:
        return len(self._by_sig)

    def add(self, task_id: str, sig: str) -> None:
        self._by_sig[sig] = task_id
        self._by_task[task_id] = sig

    def remove_task(self, task_id: str) -> None:
        sig = self._by_task.pop(task_id, None)
        if sig is not None and self._by_sig.get(sig) == task_id:
            del self._by_sig[sig]

    def lookup(self, sig: str) -> Optional[str]:
        return self._by_sig.get(sig)

    def sig_of(self, task_id: str) -> Optional[str]:
        return self._by_task.get(task_id)

    def add_dataflow(self, df: Dataflow) -> Dict[str, str]:
        sigs = compute_signatures(df)
        for tid, sig in sigs.items():
            self.add(tid, sig)
        return sigs

    def remove_tasks(self, task_ids: Iterable[str]) -> None:
        for tid in task_ids:
            self.remove_task(tid)


def is_dedup_fast(df: Dataflow) -> bool:
    """De-dup check via signatures: no two tasks share a signature."""
    sigs = compute_signatures(df)
    return len(set(sigs.values())) == len(sigs)


def dedup_fast(df: Dataflow) -> Dataflow:
    """Signature-based de-duplication (O(V+E)); mirrors equivalence.dedup."""
    sigs = compute_signatures(df)
    rep: Dict[str, str] = {}
    first: Dict[str, str] = {}
    for tid in df.topological_order():
        s = sigs[tid]
        if s in first:
            rep[tid] = first[s]
        else:
            first[s] = tid
            rep[tid] = tid
    out = Dataflow(df.name)
    for tid in df.topological_order():
        if rep[tid] == tid:
            out.add_task(df.tasks[tid])
    for s_up, s_down in df.streams:
        u, d = rep[s_up], rep[s_down]
        if u != d and (u, d) not in out.streams:
            out.add_stream(u, d)
    return out
