"""Pluggable equivalence-strategy registry for the Reusable Dataflow Manager.

The paper fixes one equivalence engine (the §3.2 bijection check); this
reproduction grew a second (the Merkle-signature fast path) and a baseline
("none", the Default of §5). Rather than a stringly-typed switch inside
:class:`repro.core.manager.ReuseManager`, each engine is a
:class:`MergeStrategy` registered by name — new engines (e.g. approximate
or cost-aware matching) plug in without editing the manager:

    @register_strategy
    class MyStrategy(MergeStrategy):
        name = "mine"
        def plan(self, mgr, df, merged_name, sigs=None): ...

``ReuseManager(strategy=...)`` accepts either a registered name or a
strategy instance.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Callable, Dict, List, Optional, Type, Union

from .graph import Dataflow
from .merge import MergePlan, _match_faithful, _match_signature, build_plan, find_overlapping
from .signatures import compute_signatures

if TYPE_CHECKING:  # pragma: no cover - import cycle guard (manager imports us)
    from .manager import ReuseManager


class MergeStrategy:
    """Equivalence engine interface used by the manager's submit/remove.

    Class attributes describe capabilities:
      * ``reuses`` — False for the no-reuse Default baseline; the manager
        then plans every submission afresh.
      * ``supports_batch`` — True when :meth:`repro.core.manager.ReuseManager.submit_many`
        may use the batch-aware planner (one signature pass + one merged-DAG
        rebuild per connected group) instead of N sequential submits.
      * ``wants_signatures`` — True when :meth:`plan` benefits from the
        precomputed Merkle signatures of the submitted DAG.
    """

    name: str = ""
    reuses: bool = True
    supports_batch: bool = False
    wants_signatures: bool = False

    def plan(
        self,
        mgr: "ReuseManager",
        df: Dataflow,
        merged_name: str,
        sigs: Optional[Dict[str, str]] = None,
    ) -> MergePlan:
        raise NotImplementedError

    def batch_match(
        self,
        mgr: "ReuseManager",
        df: Dataflow,
        sigs: Dict[str, str],
        overlap_tasks,
        created_by_sig: Dict[str, str],
    ) -> Dict[str, str]:
        """Match one batch member against the running overlap *plus* tasks
        already planned by earlier batch members (``created_by_sig``).

        Required when ``supports_batch`` is True — the manager's
        :meth:`~repro.core.manager.ReuseManager.submit_many` delegates all
        batch matching here so custom engines keep their own semantics.
        """
        raise NotImplementedError(
            f"strategy {self.name!r} sets supports_batch but does not implement batch_match"
        )

    # -- lifecycle hooks (index maintenance etc.) ---------------------------
    def on_merged(
        self,
        mgr: "ReuseManager",
        df: Dataflow,
        plan: MergePlan,
        sigs: Optional[Dict[str, str]] = None,
    ) -> None:
        pass

    def on_unmerged(self, mgr: "ReuseManager", terminated_tasks) -> None:
        pass

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"<{type(self).__name__} {self.name!r}>"


_STRATEGIES: Dict[str, Type[MergeStrategy]] = {}


def register_strategy(cls: Type[MergeStrategy]) -> Type[MergeStrategy]:
    """Class decorator: register ``cls`` under ``cls.name``."""
    if not cls.name:
        raise ValueError(f"strategy class {cls.__name__} has no name")
    if cls.name in _STRATEGIES:
        raise ValueError(f"equivalence strategy {cls.name!r} already registered")
    _STRATEGIES[cls.name] = cls
    return cls


def available_strategies() -> List[str]:
    return sorted(_STRATEGIES)


def resolve_strategy(strategy: Union[str, MergeStrategy, Type[MergeStrategy]]) -> MergeStrategy:
    """Name / instance / class → strategy instance (names hit the registry)."""
    if isinstance(strategy, MergeStrategy):
        return strategy
    if isinstance(strategy, type) and issubclass(strategy, MergeStrategy):
        return strategy()
    if isinstance(strategy, str):
        cls = _STRATEGIES.get(strategy)
        if cls is None:
            raise ValueError(
                f"unknown strategy {strategy!r} (registered: {', '.join(available_strategies())})"
            )
        return cls()
    raise TypeError(f"strategy must be a name or MergeStrategy, got {type(strategy).__name__}")


# -- built-in engines ---------------------------------------------------------


@register_strategy
class SignatureStrategy(MergeStrategy):
    """Merkle-signature index matching — beyond-paper O(V+E) fast path."""

    name = "signature"
    supports_batch = True
    wants_signatures = True

    def plan(self, mgr, df, merged_name, sigs=None):
        overlapping = find_overlapping(mgr.running, df)
        matches = _match_signature(mgr.index, mgr.running, overlapping, df, sigs=sigs)
        return build_plan(df, matches, overlapping, mgr._mint_task_id, merged_name)

    def batch_match(self, mgr, df, sigs, overlap_tasks, created_by_sig):
        matches: Dict[str, str] = {}
        for tid, sig in sigs.items():
            hit = mgr.index.lookup(sig)
            if hit is not None and hit in overlap_tasks:
                matches[tid] = hit
            elif sig in created_by_sig:
                # Cross-submission dedup: an earlier batch member already
                # planned an equivalent task — reuse it, pay nothing.
                matches[tid] = created_by_sig[sig]
        return matches

    def on_merged(self, mgr, df, plan, sigs=None):
        # A created running task is equivalent to its submitted counterpart,
        # so it inherits that signature.
        if sigs is None:
            sigs = compute_signatures(df)
        for sub_id, run_id in plan.created.items():
            mgr.index.add(run_id, sigs[sub_id])

    def on_unmerged(self, mgr, terminated_tasks):
        mgr.index.remove_tasks(terminated_tasks)


@register_strategy
class FaithfulStrategy(MergeStrategy):
    """The paper's §3.2 ancestor-graph bijection check."""

    name = "faithful"

    def plan(self, mgr, df, merged_name, sigs=None):
        overlapping = find_overlapping(mgr.running, df)
        merged_view = Dataflow("__Y__")
        for name in overlapping:
            for t in mgr.running[name].tasks.values():
                merged_view.add_task(t)
            for s in mgr.running[name].streams:
                merged_view.add_stream(*s)
        matches = _match_faithful(merged_view, df)
        return build_plan(df, matches, overlapping, mgr._mint_task_id, merged_name)


@register_strategy
class NoReuseStrategy(MergeStrategy):
    """The Default baseline — every submission runs independently (§5)."""

    name = "none"
    reuses = False

    def plan(self, mgr, df, merged_name, sigs=None):
        plan = MergePlan(submitted_name=df.name, merged_name=merged_name, overlapping=[])
        for tid in df.topological_order():
            plan.created[tid] = mgr._mint_task_id(df.tasks[tid].type)
        for s_up, s_down in df.streams:
            plan.new_streams_internal.append((plan.created[s_up], plan.created[s_down]))
        return plan
