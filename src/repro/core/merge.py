"""Merging algorithm — paper §4.1.

Given a newly submitted de-dup DAG ``D_n`` and the set of running DAGs
``D̄``, find the overlapping running DAGs ``Y`` (shared source pruning),
compute the maximal ancestor intersection, reuse the overlapping tasks
``T_o``/streams ``S_o``, and instantiate only the non-overlapping remainder
``T_x`` plus internal streams ``S_x*`` and boundary streams ``S_x⁺``.

Two equivalence strategies are supported:
  * ``"faithful"`` — the paper's bijection check over ancestor graphs.
  * ``"signature"`` — the Merkle-signature index (beyond-paper fast path).
Both produce identical plans (cross-checked by tests).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set, Tuple

from .equivalence import EquivalenceChecker
from .graph import Dataflow, Stream, Task
from .signatures import SignatureIndex, compute_signatures


@dataclass
class MergePlan:
    """Everything the data plane needs to enact a merge."""

    submitted_name: str
    merged_name: str
    overlapping: List[str]  # names of running DAGs in Y (to be replaced)
    # submitted task id -> running task id for tasks reused from D̄ (⊇ T_o cover)
    reused: Dict[str, str] = field(default_factory=dict)
    # submitted task id -> freshly minted running task id (T_x)
    created: Dict[str, str] = field(default_factory=dict)
    new_streams_internal: List[Stream] = field(default_factory=list)  # S_x* (running ids)
    new_streams_boundary: List[Stream] = field(default_factory=list)  # S_x⁺ (running ids)

    @property
    def task_map(self) -> Dict[str, str]:
        """submitted id → running id over all tasks of D_n."""
        out = dict(self.reused)
        out.update(self.created)
        return out

    @property
    def num_reused(self) -> int:
        return len(self.reused)

    @property
    def num_created(self) -> int:
        return len(self.created)


def find_overlapping(running: Dict[str, Dataflow], submitted: Dataflow) -> List[str]:
    """Y = {D̄_i : T̄_i ∩ T_n ∩ R ≠ ∅} — source-task pruning (paper §4.1).

    Source tasks are abstractly identified by their ``type`` (config is the
    constant 'SOURCE'), so the intersection tests source-type overlap.
    """
    new_sources = submitted.source_types
    return [name for name, df in running.items() if df.source_types & new_sources]


def _match_faithful(merged: Dataflow, submitted: Dataflow) -> Dict[str, str]:
    """submitted task id → equivalent running task id, via bijection check."""
    checker = EquivalenceChecker(submitted, merged)
    matches: Dict[str, str] = {}
    # Topological order: a task can only match if all its parents matched,
    # which prunes the pairwise search dramatically.
    order = submitted.topological_order()
    candidates_by_abstract: Dict[Tuple[str, str], List[str]] = {}
    for tid, t in merged.tasks.items():
        candidates_by_abstract.setdefault((t.type, t.config), []).append(tid)
    for tid in order:
        t = submitted.tasks[tid]
        if not t.is_source and not all(p in matches for p in submitted.parents(tid)):
            continue
        for cand in candidates_by_abstract.get((t.type, t.config), ()):
            if checker.equivalent(tid, cand):
                matches[tid] = cand
                break
    return matches


def _match_signature(
    index: SignatureIndex,
    running: Dict[str, Dataflow],
    overlapping: List[str],
    submitted: Dataflow,
    sigs: Optional[Dict[str, str]] = None,
) -> Dict[str, str]:
    """submitted task id → running task id via the signature index.

    Any index hit necessarily lies in an overlapping running DAG (equal
    signatures imply equal source ancestry), so the global index is safe.
    ``sigs`` may carry precomputed signatures of ``submitted`` to avoid a
    redundant hashing pass (the batched-submit path computes them once).
    """
    overlap_tasks: Set[str] = set()
    for name in overlapping:
        overlap_tasks |= set(running[name].tasks)
    if sigs is None:
        sigs = compute_signatures(submitted)
    matches: Dict[str, str] = {}
    for tid, sig in sigs.items():
        hit = index.lookup(sig)
        if hit is not None and hit in overlap_tasks:
            matches[tid] = hit
    return matches


def build_plan(
    submitted: Dataflow,
    matches: Dict[str, str],
    overlapping: List[str],
    mint_id: Callable[[str], str],
    merged_name: str,
) -> MergePlan:
    """Assemble a :class:`MergePlan` from an equivalence match.

    ``matches`` maps submitted task ids to already-running task ids (T_o);
    everything else becomes T_x with freshly minted ids, and streams are
    split into internal (S_x*) and boundary (S_x⁺) — paper §4.1.
    """
    plan = MergePlan(
        submitted_name=submitted.name, merged_name=merged_name, overlapping=list(overlapping)
    )
    plan.reused = dict(matches)
    # T_x = T_n \ T_o — tasks to instantiate with fresh running ids.
    for tid in submitted.topological_order():
        if tid not in matches:
            plan.created[tid] = mint_id(submitted.tasks[tid].type)
    # S_x = S_x* ∪ S_x⁺ — paper §4.1. (up ∉ T_o ∧ down ∈ T_o is impossible:
    # a matched task's ancestors are all matched.)
    for s_up, s_down in submitted.streams:
        if s_down in matches:
            continue  # stream already present among reused tasks
        if s_up in matches:
            plan.new_streams_boundary.append((matches[s_up], plan.created[s_down]))
        else:
            plan.new_streams_internal.append((plan.created[s_up], plan.created[s_down]))
    return plan


def plan_merge(
    running: Dict[str, Dataflow],
    submitted: Dataflow,
    mint_id: Callable[[str], str],
    merged_name: str,
    strategy: str = "signature",
    index: Optional[SignatureIndex] = None,
) -> MergePlan:
    """Compute the merge plan for ``submitted`` against the running set."""
    overlapping = find_overlapping(running, submitted)

    if strategy == "signature":
        if index is None:
            raise ValueError("signature strategy requires a SignatureIndex")
        matches = _match_signature(index, running, overlapping, submitted)
    elif strategy == "faithful":
        merged_view = Dataflow("__Y__")
        for name in overlapping:
            for t in running[name].tasks.values():
                merged_view.add_task(t)
            for s in running[name].streams:
                merged_view.add_stream(*s)
        matches = _match_faithful(merged_view, submitted)
    else:
        raise ValueError(f"unknown equivalence strategy {strategy!r}")

    return build_plan(submitted, matches, overlapping, mint_id, merged_name)


def apply_merge(
    running: Dict[str, Dataflow], submitted: Dataflow, plan: MergePlan
) -> Dataflow:
    """Enact the plan: build D̄_m, replace Y in the running set, return D̄_m."""
    merged = Dataflow(plan.merged_name)
    for name in plan.overlapping:
        for t in running[name].tasks.values():
            merged.add_task(t)
        for s in running[name].streams:
            merged.add_stream(*s)
    for sub_id, run_id in plan.created.items():
        t = submitted.tasks[sub_id]
        merged.add_task(Task(id=run_id, type=t.type, config=t.config))
    for s in plan.new_streams_internal:
        merged.add_stream(*s)
    for s in plan.new_streams_boundary:
        merged.add_stream(*s)
    for name in plan.overlapping:
        del running[name]
    running[plan.merged_name] = merged
    return merged
