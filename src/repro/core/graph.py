"""Dataflow graph model — paper §3.1.

An *event* is a discrete unit of data with an opaque payload. An *abstract
task* is ``⟨type, config⟩`` — user logic parameterized by a config. A
*concrete task* additionally carries a globally unique ``id``. A *stream* is
a directed edge transferring events from an upstream task to a downstream
task. A *dataflow* is a DAG of concrete tasks and streams.

Source tasks have ``config == 'SOURCE'`` and no inputs; sink tasks have
``config == 'SINK'`` and no outputs (paper §3.1).
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Dict, FrozenSet, Iterable, Iterator, List, Mapping, Optional, Set, Tuple

SOURCE_CONFIG = "SOURCE"
SINK_CONFIG = "SINK"


def canonical_config(config: Any) -> str:
    """Canonical string form of a task config (order-insensitive for dicts).

    Config equality in the paper (τ_i.config = τ_j.config) is implemented as
    equality of this canonical JSON form.
    """
    if isinstance(config, str):
        return config
    return json.dumps(config, sort_keys=True, separators=(",", ":"), default=str)


@dataclass(frozen=True)
class AbstractTask:
    """τ = ⟨type, config⟩ — paper §3.1."""

    type: str
    config: str  # canonical form

    @classmethod
    def of(cls, type: str, config: Any) -> "AbstractTask":
        return cls(type=type, config=canonical_config(config))

    @property
    def is_source(self) -> bool:
        return self.config == SOURCE_CONFIG

    @property
    def is_sink(self) -> bool:
        return self.config == SINK_CONFIG


@dataclass(frozen=True)
class Task:
    """Concrete task t = ⟨id, type, config⟩ — paper §3.1."""

    id: str
    type: str
    config: str  # canonical form

    @classmethod
    def make(cls, id: str, type: str, config: Any) -> "Task":
        return cls(id=id, type=type, config=canonical_config(config))

    @property
    def abstract(self) -> AbstractTask:
        return AbstractTask(self.type, self.config)

    @property
    def is_source(self) -> bool:
        return self.config == SOURCE_CONFIG

    @property
    def is_sink(self) -> bool:
        return self.config == SINK_CONFIG

    def type_similar(self, other: "Task") -> bool:
        """t_i ≈T t_j — paper §3.2."""
        return self.type == other.type

    def config_similar(self, other: "Task") -> bool:
        """t_i ≈C t_j — paper §3.2."""
        return self.type == other.type and self.config == other.config


Stream = Tuple[str, str]  # s = ⟨t_up.id, t_down.id⟩


class DataflowError(ValueError):
    pass


class Dataflow:
    """D = ⟨T, S⟩ — a DAG of concrete tasks and streams (paper §3.1).

    Mutable container used both for user-submitted dataflows and for the
    running (merged) dataflows maintained by the manager.
    """

    __slots__ = ("name", "tasks", "streams", "_children", "_parents")

    def __init__(self, name: str, tasks: Iterable[Task] = (), streams: Iterable[Stream] = ()):
        self.name = name
        self.tasks: Dict[str, Task] = {}
        self.streams: Set[Stream] = set()
        self._children: Dict[str, Set[str]] = {}
        self._parents: Dict[str, Set[str]] = {}
        for t in tasks:
            self.add_task(t)
        for s in streams:
            self.add_stream(*s)

    # -- construction ------------------------------------------------------
    def add_task(self, task: Task) -> Task:
        if task.id in self.tasks:
            existing = self.tasks[task.id]
            if existing != task:
                raise DataflowError(f"duplicate task id {task.id!r} with different definition")
            return existing
        self.tasks[task.id] = task
        self._children.setdefault(task.id, set())
        self._parents.setdefault(task.id, set())
        return task

    def add_stream(self, up_id: str, down_id: str) -> Stream:
        if up_id not in self.tasks or down_id not in self.tasks:
            raise DataflowError(f"stream ({up_id!r}→{down_id!r}) references unknown task")
        if up_id == down_id:
            raise DataflowError(f"self-loop stream on {up_id!r}")
        s = (up_id, down_id)
        self.streams.add(s)
        self._children[up_id].add(down_id)
        self._parents[down_id].add(up_id)
        return s

    def remove_task(self, task_id: str) -> None:
        if task_id not in self.tasks:
            raise DataflowError(f"unknown task {task_id!r}")
        for s in [s for s in self.streams if task_id in s]:
            self.remove_stream(*s)
        del self.tasks[task_id]
        del self._children[task_id]
        del self._parents[task_id]

    def remove_stream(self, up_id: str, down_id: str) -> None:
        self.streams.discard((up_id, down_id))
        self._children.get(up_id, set()).discard(down_id)
        self._parents.get(down_id, set()).discard(up_id)

    # -- accessors ----------------------------------------------------------
    def __contains__(self, task_id: str) -> bool:
        return task_id in self.tasks

    def __len__(self) -> int:
        return len(self.tasks)

    def parents(self, task_id: str) -> Set[str]:
        """π_D(t) — immediate upstream predecessors (paper §3.2)."""
        return set(self._parents.get(task_id, set()))

    def children(self, task_id: str) -> Set[str]:
        return set(self._children.get(task_id, set()))

    @property
    def source_ids(self) -> List[str]:
        """I = T ∩ R — input (source) tasks."""
        return [t.id for t in self.tasks.values() if t.is_source]

    @property
    def sink_ids(self) -> List[str]:
        """O = T ∩ N — output (sink) tasks."""
        return [t.id for t in self.tasks.values() if t.is_sink]

    @property
    def source_types(self) -> Set[str]:
        """Abstract identity of source tasks (type uniquely names a source)."""
        return {t.type for t in self.tasks.values() if t.is_source}

    def topological_order(self) -> List[str]:
        """Kahn topological order; raises on cycles."""
        indeg = {tid: len(self._parents[tid]) for tid in self.tasks}
        frontier = sorted(tid for tid, d in indeg.items() if d == 0)
        order: List[str] = []
        import heapq

        heapq.heapify(frontier)
        while frontier:
            tid = heapq.heappop(frontier)
            order.append(tid)
            for c in self._children[tid]:
                indeg[c] -= 1
                if indeg[c] == 0:
                    heapq.heappush(frontier, c)
        if len(order) != len(self.tasks):
            raise DataflowError(f"dataflow {self.name!r} has a cycle")
        return order

    def validate(self) -> None:
        """Structural validation: acyclic, connected, sources/sinks well-formed.

        Weak connectivity is required of *submitted* dataflows: the paper's
        Δ/Φ bookkeeping (§4.2) assumes each submission lives in exactly one
        running DAG, which only holds when the submission is one weakly
        connected application. A disconnected submission should be split by
        the user into separate dataflows.
        """
        self.topological_order()
        for t in self.tasks.values():
            if t.is_source and self._parents[t.id]:
                raise DataflowError(f"source task {t.id!r} has input streams")
            if t.is_sink and self._children[t.id]:
                raise DataflowError(f"sink task {t.id!r} has output streams")
        for tid in self.tasks:
            t = self.tasks[tid]
            if not t.is_source and not self._parents[tid]:
                raise DataflowError(f"non-source task {tid!r} has no input streams")
        if len(self.tasks) and len(self.connected_components()) > 1:
            raise DataflowError(
                f"dataflow {self.name!r} is not weakly connected; submit "
                f"each component as its own dataflow"
            )

    def connected_components(self) -> List[Set[str]]:
        """Weakly connected components (used by unmerge — paper §4.2)."""
        seen: Set[str] = set()
        comps: List[Set[str]] = []
        for start in self.tasks:
            if start in seen:
                continue
            comp: Set[str] = set()
            stack = [start]
            while stack:
                tid = stack.pop()
                if tid in comp:
                    continue
                comp.add(tid)
                stack.extend(self._children[tid] - comp)
                stack.extend(self._parents[tid] - comp)
            seen |= comp
            comps.append(comp)
        return comps

    def subgraph(self, name: str, task_ids: Set[str]) -> "Dataflow":
        tasks = [self.tasks[tid] for tid in task_ids]
        streams = [s for s in self.streams if s[0] in task_ids and s[1] in task_ids]
        return Dataflow(name, tasks, streams)

    def copy(self, name: Optional[str] = None) -> "Dataflow":
        return Dataflow(name or self.name, self.tasks.values(), self.streams)

    def __repr__(self) -> str:
        return f"Dataflow({self.name!r}, |T|={len(self.tasks)}, |S|={len(self.streams)})"

    # -- (de)serialization ---------------------------------------------------
    def to_json(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "tasks": [
                {"id": t.id, "type": t.type, "config": t.config} for t in self.tasks.values()
            ],
            "streams": sorted(list(s) for s in self.streams),
        }

    @classmethod
    def from_json(cls, obj: Mapping[str, Any]) -> "Dataflow":
        df = cls(obj["name"])
        for t in obj["tasks"]:
            df.add_task(Task.make(t["id"], t["type"], t["config"]))
        for up, down in obj["streams"]:
            df.add_stream(up, down)
        return df


def up(s: Stream) -> str:
    """up(s) — paper §3.1."""
    return s[0]


def down(s: Stream) -> str:
    """down(s) — paper §3.1."""
    return s[1]
