"""Ancestor graphs and task equivalence — paper §3.2.

This module is the *faithful* implementation of the paper's equivalence
machinery: explicit ancestor-graph construction (the recurrence α_D(t)) and
an explicit bijection check between ancestor graphs. The O(V+E) Merkle
signature fast path lives in :mod:`repro.core.signatures`; the two are
cross-checked against each other in the test suite.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, FrozenSet, List, Optional, Set, Tuple

from .graph import Dataflow, Stream, Task


@dataclass(frozen=True)
class AncestorGraph:
    """α_D(t) → A⟨T̄, S̄⟩ — the task, all its ancestors, and their streams."""

    root: str  # task id the graph was derived for
    task_ids: FrozenSet[str]
    streams: FrozenSet[Stream]

    def __len__(self) -> int:
        return len(self.task_ids)

    def is_sub_ancestor_of(self, other: "AncestorGraph") -> bool:
        """A_j ⊂ A_i (strict) — paper §3.2 'sub-ancestor'."""
        return (
            self.task_ids <= other.task_ids
            and self.streams <= other.streams
            and (self.task_ids != other.task_ids or self.streams != other.streams)
        )


def ancestor_graph(df: Dataflow, task_id: str) -> AncestorGraph:
    """Compute α_D(t) iteratively (the paper's recurrence, memo-free)."""
    if task_id not in df.tasks:
        raise KeyError(task_id)
    tasks: Set[str] = set()
    streams: Set[Stream] = set()
    stack = [task_id]
    while stack:
        tid = stack.pop()
        if tid in tasks:
            continue
        tasks.add(tid)
        for p in df.parents(tid):
            streams.add((p, tid))
            if p not in tasks:
                stack.append(p)
    return AncestorGraph(task_id, frozenset(tasks), frozenset(streams))


def ancestor_graph_set(df: Dataflow) -> List[AncestorGraph]:
    """𝔸 = {α_D(t) | t ∈ T} — paper §3.2."""
    return [ancestor_graph(df, tid) for tid in df.tasks]


def maximal(graphs: List[AncestorGraph]) -> List[AncestorGraph]:
    """Ω — keep only ancestor graphs that are not sub-ancestors of another.

    Paper §3.2 'maximal ancestor graph set'.
    """
    out: List[AncestorGraph] = []
    for g in graphs:
        if not any(g.is_sub_ancestor_of(h) for h in graphs if h is not g):
            out.append(g)
    return out


class EquivalenceChecker:
    """Memoized pairwise task-equivalence between two dataflows.

    t_i ↔ t_j ⟺ t_i ≈C t_j AND their ancestor graphs admit a bijection ε of
    config-similar tasks (paper §3.2). For *de-dup* DAGs the bijection, when
    it exists, is unique, so a recursive one-to-one parent matching decides
    equivalence without backtracking: two tasks are equivalent iff they are
    config-similar and their parent sets match one-to-one under equivalence.

    The memo also *constructs* ε (as ``self.witness``) so the merge algorithm
    can rewire boundary streams onto the matched running tasks.
    """

    def __init__(self, df_a: Dataflow, df_b: Dataflow):
        self.a = df_a
        self.b = df_b
        self._memo: Dict[Tuple[str, str], bool] = {}

    def equivalent(self, ta: str, tb: str) -> bool:
        key = (ta, tb)
        hit = self._memo.get(key)
        if hit is not None:
            return hit
        # Guard against pathological recursion on deep chains.
        self._memo[key] = False  # provisional (DAGs ⇒ no true cycles)
        result = self._check(ta, tb)
        self._memo[key] = result
        return result

    def _check(self, ta: str, tb: str) -> bool:
        task_a = self.a.tasks[ta]
        task_b = self.b.tasks[tb]
        if not task_a.config_similar(task_b):
            return False
        pa = self.a.parents(ta)
        pb = self.b.parents(tb)
        if len(pa) != len(pb):
            return False
        if not pa:  # both sources (or parentless) — config-similar suffices
            return True
        # One-to-one matching of parents under equivalence. De-dup DAGs make
        # the match unique; we still verify injectivity for safety.
        unmatched_b = set(pb)
        for p in pa:
            match = None
            for q in unmatched_b:
                if self.equivalent(p, q):
                    match = q
                    break
            if match is None:
                return False
            unmatched_b.discard(match)
        return not unmatched_b

    def witness(self, ta: str, tb: str) -> Optional[Dict[str, str]]:
        """Construct ε : ancestors(ta) → ancestors(tb) if equivalent, else None."""
        if not self.equivalent(ta, tb):
            return None
        mapping: Dict[str, str] = {}
        stack = [(ta, tb)]
        while stack:
            x, y = stack.pop()
            if x in mapping:
                continue
            mapping[x] = y
            unmatched = set(self.b.parents(y))
            for p in self.a.parents(x):
                for q in list(unmatched):
                    if self.equivalent(p, q):
                        stack.append((p, q))
                        unmatched.discard(q)
                        break
        return mapping


def find_equivalent_tasks(df_a: Dataflow, df_b: Dataflow) -> Dict[str, str]:
    """All pairs (t_a → t_b) with t_a ↔ t_b; at most one match per task in a
    de-dup DAG. Used to build the ancestor intersection Λ (paper §3.2)."""
    checker = EquivalenceChecker(df_a, df_b)
    out: Dict[str, str] = {}
    for ta in df_a.tasks:
        for tb in df_b.tasks:
            if checker.equivalent(ta, tb):
                out[ta] = tb
                break
    return out


def ancestor_intersection(df_a: Dataflow, df_b: Dataflow) -> List[AncestorGraph]:
    """Λ(D_i, D_j) — ancestor graphs (taken from D_i) of equivalent tasks."""
    matches = find_equivalent_tasks(df_a, df_b)
    return [ancestor_graph(df_a, ta) for ta in matches]


def maximal_ancestor_intersection(df_a: Dataflow, df_b: Dataflow) -> List[AncestorGraph]:
    """Λ̂(D_i, D_j) = Ω(Λ(D_i, D_j)) — paper §3.2."""
    return maximal(ancestor_intersection(df_a, df_b))


def dataflows_disjoint(df_a: Dataflow, df_b: Dataflow) -> bool:
    """D_i ↮ D_j — no equivalent task pair exists (paper §3.2)."""
    return not find_equivalent_tasks(df_a, df_b)


def is_dedup(df: Dataflow) -> bool:
    """A de-dup DAG has no two internally equivalent tasks (paper §3.2)."""
    checker = EquivalenceChecker(df, df)
    tids = list(df.tasks)
    for i, ta in enumerate(tids):
        for tb in tids[i + 1 :]:
            if checker.equivalent(ta, tb):
                return False
    return True


def dedup(df: Dataflow) -> Dataflow:
    """Collapse internally-equivalent tasks (utility; submitted DAGs are
    required to be de-dup, this canonicalizes user input)."""
    checker = EquivalenceChecker(df, df)
    order = df.topological_order()
    rep: Dict[str, str] = {}  # task id -> representative id
    for i, tid in enumerate(order):
        for prev in order[:i]:
            if rep.get(prev, prev) == prev and checker.equivalent(tid, prev):
                rep[tid] = prev
                break
        rep.setdefault(tid, tid)
    out = Dataflow(df.name)
    for tid in order:
        if rep[tid] == tid:
            out.add_task(df.tasks[tid])
    for s_up, s_down in df.streams:
        u, d = rep[s_up], rep[s_down]
        if u != d and (u, d) not in out.streams:
            out.add_stream(u, d)
    return out
