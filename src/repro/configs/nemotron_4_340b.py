"""nemotron-4-340b [dense] — GQA, squared-ReLU MLP.

96L d_model=18432 96H (GQA kv=8) d_ff=73728 vocab=256000  [arXiv:2402.16819]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    d_ff=73728,
    vocab_size=256_000,
    activation="relu2",
    norm="layernorm",
    rope_theta=10_000.0,
)

SMOKE = ModelConfig(
    name="nemotron-4-340b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    activation="relu2",
    norm="layernorm",
    dtype="float32",
    param_dtype="float32",
)
