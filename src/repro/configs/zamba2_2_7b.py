"""zamba2-2.7b [hybrid] — Mamba2 backbone + one *shared* attention block
applied every 6 layers (9 applications, weights shared).

54L d_model=2560 32H (kv=32) d_ff=10240 ssm_state=64  [arXiv:2411.15242; hf]
Long-context adaptation (DESIGN.md §6.1): the shared attention block uses a
4096 sliding window so the long_500k decode cell holds O(window) KV state.
"""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32_000,
    norm="rmsnorm",
    swa_window=4096,
    shared_attn_every=6,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=128),
)

SMOKE = ModelConfig(
    name="zamba2-2.7b-smoke",
    family="hybrid",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    norm="rmsnorm",
    swa_window=16,
    shared_attn_every=2,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=32, chunk=8),
    dtype="float32",
    param_dtype="float32",
)
