"""llama-3.2-vision-90b [vlm] — gated cross-attn image layers every 5th.

100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256
[hf:meta-llama/Llama-3.2-11B-Vision]  The vision frontend is a STUB:
``input_specs`` supplies precomputed patch embeddings (B, 1024, D).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128_256,
    activation="swiglu",
    norm="rmsnorm",
    rope_theta=500_000.0,
    cross_attn_every=5,
    num_image_tokens=1024,
)

SMOKE = ModelConfig(
    name="llama-3.2-vision-90b-smoke",
    family="vlm",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    activation="swiglu",
    norm="rmsnorm",
    cross_attn_every=2,
    num_image_tokens=16,
    dtype="float32",
    param_dtype="float32",
)
