"""xlstm-1.3b [ssm] — mLSTM blocks with every 8th an sLSTM block (7:1).

48L d_model=2048 4H d_ff=0 vocab=50304  [arXiv:2405.04517]
Sub-quadratic (O(1) recurrent state) → runs the long_500k cell.
"""
from repro.models.config import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    norm="rmsnorm",
    xlstm=XLSTMConfig(slstm_every=8, mlstm_proj_factor=2.0, slstm_ff_factor=1.333, chunk=64),
)

SMOKE = ModelConfig(
    name="xlstm-1.3b-smoke",
    family="ssm",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=512,
    norm="rmsnorm",
    xlstm=XLSTMConfig(slstm_every=2, mlstm_proj_factor=2.0, slstm_ff_factor=1.333, chunk=8),
    dtype="float32",
    param_dtype="float32",
)
