"""Assigned architecture configs (exact published sizes) + reduced smoke
variants + the four assigned input-shape cells.

``get_config(arch)`` returns the full config; ``get_smoke_config(arch)``
returns a structurally identical reduced config for CPU tests.
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.models.config import ModelConfig

ARCHS: Tuple[str, ...] = (
    "granite_20b",
    "nemotron_4_340b",
    "qwen15_110b",
    "qwen3_4b",
    "deepseek_v2_236b",
    "mixtral_8x22b",
    "llama32_vision_90b",
    "xlstm_1_3b",
    "zamba2_2_7b",
    "seamless_m4t_medium",
)

# public --arch ids (hyphenated, as assigned) → module names
ALIASES: Dict[str, str] = {
    "granite-20b": "granite_20b",
    "nemotron-4-340b": "nemotron_4_340b",
    "qwen1.5-110b": "qwen15_110b",
    "qwen3-4b": "qwen3_4b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "mixtral-8x22b": "mixtral_8x22b",
    "llama-3.2-vision-90b": "llama32_vision_90b",
    "xlstm-1.3b": "xlstm_1_3b",
    "zamba2-2.7b": "zamba2_2_7b",
    "seamless-m4t-medium": "seamless_m4t_medium",
}


@dataclass(frozen=True)
class ShapeCell:
    """One assigned (input-shape) cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", 4_096, 256, "train"),
    ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    ShapeCell("decode_32k", 32_768, 128, "decode"),
    ShapeCell("long_500k", 524_288, 1, "decode"),
)


def shape_cell(name: str) -> ShapeCell:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def _module(arch: str):
    key = ALIASES.get(arch, arch)
    return importlib.import_module(f"repro.configs.{key}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).SMOKE


def cell_supported(cfg: ModelConfig, cell: ShapeCell) -> Optional[str]:
    """None if the (arch × shape) cell runs; else the documented skip reason."""
    if cell.name == "long_500k" and not cfg.sub_quadratic:
        return "SKIP(long-context: full attention)"
    return None


def all_cells() -> List[Tuple[str, ShapeCell]]:
    return [(a, s) for a in ARCHS for s in SHAPES]
