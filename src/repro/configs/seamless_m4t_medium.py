"""seamless-m4t-medium [audio] — encoder-decoder, multimodal.

12L(enc) + 12L(dec) d_model=1024 16H d_ff=4096 vocab=256206
[arXiv:2308.11596; hf]  The speech frontend is a STUB: ``input_specs``
supplies precomputed frame embeddings (B, 1024, D). vocab padded to 256
multiple for clean vocab-parallel sharding (256206 → 256256).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256_206,
    vocab_pad_to=256,
    activation="gelu",
    norm="layernorm",
    n_encoder_layers=12,
    encoder_seq=1024,
)

SMOKE = ModelConfig(
    name="seamless-m4t-medium-smoke",
    family="audio",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=510,
    vocab_pad_to=64,
    activation="gelu",
    norm="layernorm",
    n_encoder_layers=2,
    encoder_seq=16,
    dtype="float32",
    param_dtype="float32",
)
