"""deepseek-v2-236b [moe] — MLA (kv_lora=512), 2 shared + 160 routed top-6.

60L d_model=5120 128H d_ff(expert)=1536 vocab=102400  [arXiv:2405.04434; hf]
First layer uses a dense FFN (d_ff=12288) per the published config.
"""
from repro.models.config import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_ff=1536,
    vocab_size=102_400,
    activation="swiglu",
    norm="rmsnorm",
    rope_theta=10_000.0,
    moe=MoEConfig(
        num_experts=160,
        top_k=6,
        expert_ff=1536,
        num_shared=2,
        capacity_factor=1.25,
        first_k_dense=1,
        dense_ff=12288,
    ),
    mla=MLAConfig(
        kv_lora_rank=512,
        q_lora_rank=1536,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
)

SMOKE = ModelConfig(
    name="deepseek-v2-236b-smoke",
    family="moe",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=96,
    vocab_size=512,
    activation="swiglu",
    norm="rmsnorm",
    moe=MoEConfig(
        num_experts=4,
        top_k=2,
        expert_ff=96,
        num_shared=1,
        capacity_factor=1.25,
        first_k_dense=1,
        dense_ff=128,
    ),
    mla=MLAConfig(
        kv_lora_rank=32,
        q_lora_rank=48,
        qk_nope_head_dim=16,
        qk_rope_head_dim=8,
        v_head_dim=16,
    ),
    dtype="float32",
    param_dtype="float32",
)
