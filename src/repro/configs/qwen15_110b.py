"""qwen1.5-110b [dense] — QKV bias.

80L d_model=8192 64H (GQA kv=8) d_ff=49152 vocab=152064  [hf:Qwen/Qwen1.5-0.5B]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=49152,
    vocab_size=152_064,
    qkv_bias=True,
    activation="swiglu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="qwen1.5-110b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    qkv_bias=True,
    activation="swiglu",
    norm="rmsnorm",
    dtype="float32",
    param_dtype="float32",
)
