"""qwen3-4b [dense] — qk_norm, GQA, head_dim=128 (≠ d_model/H).

36L d_model=2560 32H (GQA kv=8) d_ff=9728 vocab=151936  [hf:Qwen/Qwen3-8B]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b",
    family="dense",
    n_layers=36,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_ff=9728,
    vocab_size=151_936,
    head_dim=128,
    qk_norm=True,
    activation="swiglu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="qwen3-4b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    head_dim=32,
    qk_norm=True,
    activation="swiglu",
    norm="rmsnorm",
    dtype="float32",
    param_dtype="float32",
)
