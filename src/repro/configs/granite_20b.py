"""granite-20b [dense] — llama-arch code model, MQA (kv=1).

52L d_model=6144 48H (GQA kv=1) d_ff=24576 vocab=49152  [arXiv:2405.04324; hf]

GELU MLP (2 matrices): with the published d_ff=4·d_model, a 3-matrix
swiglu would put the model at 28B; the real granite-20b-code MLP is
gelu, landing the total at ~20B as the name says.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    activation="gelu",
    norm="rmsnorm",
    rope_theta=10_000.0,
)

SMOKE = ModelConfig(
    name="granite-20b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    d_ff=128,
    vocab_size=512,
    activation="gelu",
    norm="rmsnorm",
    dtype="float32",
    param_dtype="float32",
)
