"""mixtral-8x22b [moe] — 8 experts top-2, sliding-window attention.

56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768  [arXiv:2401.04088; hf]
SWA window 4096 (per the Mistral sliding-window design named in the
assignment) makes the long_500k decode cell O(window).
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=32_768,
    activation="swiglu",
    norm="rmsnorm",
    swa_window=4096,
    rope_theta=1_000_000.0,
    moe=MoEConfig(num_experts=8, top_k=2, expert_ff=16384, capacity_factor=1.25),
)

SMOKE = ModelConfig(
    name="mixtral-8x22b-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    activation="swiglu",
    norm="rmsnorm",
    swa_window=16,
    moe=MoEConfig(num_experts=4, top_k=2, expert_ff=128, capacity_factor=1.25),
    dtype="float32",
    param_dtype="float32",
)
