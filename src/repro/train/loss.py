"""Next-token cross-entropy with masking and z-loss.

The log-softmax runs in f32 regardless of logits dtype. ``ignore_index``
(-1) masks padding tokens out of both the loss and the denominator.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

IGNORE_INDEX = -1


def cross_entropy_loss(
    logits: jnp.ndarray,  # (B, S, V)
    labels: jnp.ndarray,  # (B, S) int32, IGNORE_INDEX = masked
    *,
    z_loss_coeff: float = 0.0,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (mean loss, token count)."""
    logits = logits.astype(jnp.float32)
    mask = labels != IGNORE_INDEX
    safe = jnp.where(mask, labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)  # (B, S)
    picked = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = lse - picked
    if z_loss_coeff:
        nll = nll + z_loss_coeff * jnp.square(lse)
    n = jnp.maximum(mask.sum(), 1)
    loss = jnp.where(mask, nll, 0.0).sum() / n
    return loss, n
