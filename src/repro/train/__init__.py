"""Training substrate: optimizer, LR schedules, loss, train step,
checkpointing with resharding, and the elastic/fault-tolerance policies."""
from .loss import cross_entropy_loss
from .optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from .step import TrainState, make_train_step, train_state_init, abstract_train_state

__all__ = [
    "AdamWConfig",
    "TrainState",
    "abstract_train_state",
    "adamw_init",
    "adamw_update",
    "cosine_schedule",
    "cross_entropy_loss",
    "make_train_step",
    "train_state_init",
]
