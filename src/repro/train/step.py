"""Train state + step factory.

The step is a pure function ``(state, batch) → (state, metrics)`` designed
for ``jax.jit`` under a mesh: with params sharded over (fsdp × model) and
the batch over the data axes, GSPMD inserts the reduce-scatter/all-gather
collectives — the step body never references the mesh.

Gradient accumulation: ``accum > 1`` scans over microbatches, accumulating
grads in ``accum_dtype`` (f32 by default; bf16 for the memory-tightest
configs). With remat on every block (see models/transformer.py) the live
activation set is one microbatch deep.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import forward
from repro.models.config import ModelConfig

from .loss import cross_entropy_loss
from .optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule

PyTree = Any
TrainState = Dict[str, Any]  # {"step", "params", "mu", "nu"}


def train_state_init(cfg: ModelConfig, opt: AdamWConfig, key: jax.Array) -> TrainState:
    from repro.models import init_params

    params = init_params(cfg, key)
    mu, nu = adamw_init(params, opt)
    return {"step": jnp.zeros((), jnp.int32), "params": params, "mu": mu, "nu": nu}


def abstract_train_state(cfg: ModelConfig, opt: AdamWConfig) -> TrainState:
    """ShapeDtypeStruct tree — the dry-run path, no allocation."""
    return jax.eval_shape(lambda: train_state_init(cfg, opt, jax.random.PRNGKey(0)))


def make_train_step(
    cfg: ModelConfig,
    opt: AdamWConfig,
    *,
    accum: int = 1,
    z_loss_coeff: float = 1e-4,
    accum_dtype: str = "float32",
) -> Callable[[TrainState, Dict[str, jnp.ndarray]], Tuple[TrainState, Dict[str, jnp.ndarray]]]:
    def loss_fn(params, tokens, labels, memory):
        logits = forward(params, cfg, tokens, memory=memory)
        loss, _ = cross_entropy_loss(logits, labels, z_loss_coeff=z_loss_coeff)
        return loss

    grad_fn = jax.value_and_grad(loss_fn)

    def train_step(state: TrainState, batch: Dict[str, jnp.ndarray]):
        tokens, labels = batch["tokens"], batch["labels"]
        memory = batch.get("memory")
        params = state["params"]

        if accum <= 1:
            loss, grads = grad_fn(params, tokens, labels, memory)
        else:
            B = tokens.shape[0]
            assert B % accum == 0, (B, accum)
            mb = B // accum

            def split(x):
                return x.reshape(accum, mb, *x.shape[1:])

            xs = (split(tokens), split(labels))
            xs += (split(memory),) if memory is not None else (None,)
            gacc = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.dtype(accum_dtype)), params
            )

            def micro(carry, x):
                gacc, lacc = carry
                t, l = x[0], x[1]
                m = x[2] if memory is not None else None
                loss_i, g = grad_fn(params, t, l, m)
                gacc = jax.tree.map(lambda a, gi: a + gi.astype(a.dtype), gacc, g)
                return (gacc, lacc + loss_i), None

            if memory is None:
                xs = (xs[0], xs[1])
            (gacc, lsum), _ = jax.lax.scan(micro, (gacc, jnp.zeros((), jnp.float32)), xs)
            grads = jax.tree.map(lambda g: (g / accum).astype(jnp.float32), gacc)
            loss = lsum / accum

        new_p, new_mu, new_nu, gnorm = adamw_update(
            grads, params, state["mu"], state["nu"], state["step"], opt
        )
        new_state = {
            "step": state["step"] + 1,
            "params": new_p,
            "mu": new_mu,
            "nu": new_nu,
        }
        metrics = {
            "loss": loss,
            "grad_norm": gnorm,
            "lr": cosine_schedule(opt)(state["step"]),
        }
        return new_state, metrics

    return train_step
