"""AdamW in pure JAX with configurable state dtypes and global-norm clip.

At 340B scale with bf16 moment states, per-chip optimizer bytes stay
inside a v5e's 16 GB HBM (params + grads + m + v = 4×2 bytes/param,
sharded over the full (data × model) mesh — see DESIGN.md §7). The state
dtypes are per-config knobs so small models can keep f32 moments.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    mu_dtype: str = "bfloat16"
    nu_dtype: str = "float32"

    def replace(self, **kw) -> "AdamWConfig":
        return dataclasses.replace(self, **kw)


def cosine_schedule(opt: AdamWConfig) -> Callable[[jnp.ndarray], jnp.ndarray]:
    def lr(step: jnp.ndarray) -> jnp.ndarray:
        step = step.astype(jnp.float32)
        # warm from step 1 so the very first update is non-zero
        warm = opt.peak_lr * (step + 1) / max(opt.warmup_steps, 1)
        frac = jnp.clip(
            (step - opt.warmup_steps) / max(opt.total_steps - opt.warmup_steps, 1), 0, 1
        )
        floor = opt.peak_lr * opt.min_lr_ratio
        cos = floor + 0.5 * (opt.peak_lr - floor) * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < opt.warmup_steps, warm, cos)

    return lr


def adamw_init(params: PyTree, opt: AdamWConfig) -> Tuple[PyTree, PyTree]:
    mu = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.dtype(opt.mu_dtype)), params)
    nu = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.dtype(opt.nu_dtype)), params)
    return mu, nu


def global_norm(tree: PyTree) -> jnp.ndarray:
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def adamw_update(
    grads: PyTree,
    params: PyTree,
    mu: PyTree,
    nu: PyTree,
    step: jnp.ndarray,  # 0-based
    opt: AdamWConfig,
) -> Tuple[PyTree, PyTree, PyTree, jnp.ndarray]:
    """Returns (new_params, new_mu, new_nu, grad_norm)."""
    gnorm = global_norm(grads)
    if opt.clip_norm:
        scale = jnp.minimum(1.0, opt.clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)
    lr = cosine_schedule(opt)(step)
    t = (step + 1).astype(jnp.float32)
    bc1 = 1 - opt.b1 ** t
    bc2 = 1 - opt.b2 ** t

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = opt.b1 * m.astype(jnp.float32) + (1 - opt.b1) * g32
        v32 = opt.b2 * v.astype(jnp.float32) + (1 - opt.b2) * jnp.square(g32)
        mh = m32 / bc1
        vh = v32 / bc2
        delta = mh / (jnp.sqrt(vh) + opt.eps)
        if opt.weight_decay:
            delta = delta + opt.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return new_p.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(mu)
    flat_v = tdef.flatten_up_to(nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, new_m, new_v, gnorm
