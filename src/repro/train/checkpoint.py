"""Checkpoint/restore with resharding — the fault-tolerance substrate.

Design (1000+-node ready):
  * **Atomic**: write to ``step_N.tmp/``, fsync, rename to ``step_N/`` —
    a crash mid-write never corrupts the latest checkpoint.
  * **Async**: ``save_async`` snapshots device arrays to host (cheap) and
    writes on a worker thread; the train loop never blocks on disk.
  * **Resharded restore**: the manifest stores *logical* shapes + dtypes
    + the PartitionSpec used; restore re-shards onto whatever mesh is
    current. A 512-chip checkpoint restores onto 256 chips after a pod
    loss (elastic resize) — the spec is re-resolved against the new mesh.
  * **Self-describing**: manifest.json carries the pytree structure, so
    restore needs no live model object.

On a real multi-host pod each host writes only its addressable shards;
here the single process holds the full array (CPU), which keeps the
format identical while the gather path is a no-op.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

PyTree = Any

_MANIFEST = "manifest.json"


def _flatten_with_names(tree: PyTree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((name, leaf))
    return out


def save(ckpt_dir: str, step: int, state: PyTree, *, specs: Optional[PyTree] = None) -> str:
    """Synchronous atomic checkpoint; returns the final directory."""
    final = os.path.join(ckpt_dir, f"step_{step}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    leaves = _flatten_with_names(state)
    spec_leaves = dict(_flatten_with_names(specs)) if specs is not None else {}
    manifest: Dict[str, Any] = {"step": step, "arrays": {}}
    for name, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        fname = name.replace("/", "__") + ".npy"
        entry = {"file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
        if arr.dtype.kind not in "biufc":  # ml_dtypes (bfloat16, fp8, …)
            entry["stored_as"] = f"uint{arr.dtype.itemsize * 8}"
            arr = arr.view(entry["stored_as"])
        np.save(os.path.join(tmp, fname), arr)
        if name in spec_leaves and spec_leaves[name] is not None:
            entry["spec"] = _spec_to_json(spec_leaves[name])
        manifest["arrays"][name] = entry
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    _gc(ckpt_dir, keep=3)
    return final


def _spec_to_json(spec) -> List[Any]:
    out = []
    for p in tuple(spec):
        if p is None:
            out.append(None)
        elif isinstance(p, (tuple, list)):
            out.append(list(p))
        else:
            out.append(p)
    return out


def _spec_from_json(obj) -> "jax.sharding.PartitionSpec":
    from jax.sharding import PartitionSpec as P

    return P(*[tuple(p) if isinstance(p, list) else p for p in obj])


class AsyncCheckpointer:
    """Snapshot-to-host then write on a daemon thread; one in flight."""

    def __init__(self, ckpt_dir: str):
        self.ckpt_dir = ckpt_dir
        self._thread: Optional[threading.Thread] = None
        self.last_path: Optional[str] = None

    def save_async(self, step: int, state: PyTree, specs: Optional[PyTree] = None) -> None:
        self.wait()
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)

        def work():
            self.last_path = save(self.ckpt_dir, step, host_state, specs=specs)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp"):
            try:
                steps.append(int(d.split("_", 1)[1]))
            except ValueError:
                pass
    return max(steps) if steps else None


def restore(
    ckpt_dir: str,
    step: Optional[int] = None,
    *,
    mesh=None,
    target: Optional[PyTree] = None,
) -> PyTree:
    """Restore (optionally resharding onto ``mesh``).

    With ``target`` (a pytree of like-structured leaves or
    ShapeDtypeStructs) the result is unflattened into that structure;
    otherwise a flat {name: array} dict is returned.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(d, _MANIFEST)) as f:
        manifest = json.load(f)

    arrays: Dict[str, Any] = {}
    for name, entry in manifest["arrays"].items():
        arr = np.load(os.path.join(d, entry["file"]))
        if "stored_as" in entry:
            import ml_dtypes  # ships with jax

            arr = arr.view(np.dtype(entry["dtype"]))
        if mesh is not None and "spec" in entry:
            spec = _spec_from_json(entry["spec"])
            # drop axes that no longer exist on the (resized) mesh
            cleaned = []
            for p in tuple(spec):
                ax = [a for a in (p if isinstance(p, tuple) else (p,))
                      if a is None or a in mesh.axis_names]
                ax = [a for a in ax if a is not None]
                cleaned.append(tuple(ax) if len(ax) > 1 else (ax[0] if ax else None))
            from jax.sharding import NamedSharding, PartitionSpec as P

            sh = NamedSharding(mesh, P(*cleaned))
            arrays[name] = jax.device_put(arr, sh)
        else:
            arrays[name] = arr
    if target is None:
        return arrays
    flat_names = [n for n, _ in _flatten_with_names(target)]
    leaves = [arrays[n] for n in flat_names]
    treedef = jax.tree.structure(target)
    return jax.tree.unflatten(treedef, leaves)


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(
        int(d.split("_", 1)[1])
        for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)
