"""ReuseSession — the facade over control plane and data plane.

One object owns the paper's §4.3 Manager lifecycle: submissions, removals,
defragmentation, execution and observability. By default the session is
control-plane only (a :class:`~repro.core.manager.ReuseManager` — cheap,
no JAX import); with ``execute=True`` it owns a full
:class:`~repro.runtime.system.StreamSystem` driving a pluggable
:class:`~repro.runtime.backend.ExecutionBackend`: ``backend="inprocess"``
(default — the jit data plane actually streams event batches),
``"sharded"`` (segments placed across ``jax.devices()``), ``"dryrun"``
(pure cost-model stepping, no JAX — full OPMW trace sweeps in
milliseconds) or ``"multiproc"`` (persistent worker processes stepping
jit segments over a shared-memory/TCP stream transport — ``workers=``
sizes the pool, ``transport=`` picks the wire).

    session = ReuseSession(strategy="signature", execute=True, backend="dryrun")
    session.on_merge(lambda ev: print("merged", ev.name, "→", ev.running_dag))
    session.on_step(lambda ev: print(ev.live_tasks, ev.cost))
    receipt = session.submit(flow("alice").source("urban")...)
    batch = session.submit_many([flow_b, flow_c])
    session.run(5)
    print(session.stats().task_reduction)

Durability: ``checkpoint_dir=`` (plus ``checkpoint_every=N`` steps for an
automatic cadence) makes the whole system crash-recoverable —
``ReuseSession.restore(checkpoint_dir)`` rebuilds control plane *and* data
plane from the newest valid checkpoint and resumes exactly where the
crashed process stopped (see :mod:`repro.runtime.checkpoint`).
"""
from __future__ import annotations

from collections import Counter
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Union

from repro.core import DataflowError, ReuseManager
from repro.core.graph import Dataflow
from repro.core.manager import RemovalReceipt, SubmissionReceipt
from repro.core.strategies import MergeStrategy

from .builder import DataflowBuilder, as_dataflow
from .events import (
    BatchSubmitReceipt,
    DefragEvent,
    MergeEvent,
    SessionStats,
    StepEvent,
    UnmergeEvent,
    WaveEvent,
)

Submittable = Union[Dataflow, DataflowBuilder]
Hook = Callable[[Any], None]


class ReuseSession:
    def __init__(
        self,
        strategy: Union[str, MergeStrategy] = "signature",
        *,
        execute: bool = False,
        backend: Union[str, Any] = "inprocess",
        base_batch: int = 32,
        check_invariants: bool = False,
        journal_path: Optional[str] = None,
        checkpoint_dir: Optional[str] = None,
        checkpoint_every: Optional[int] = None,
        checkpoint_keep_last: Optional[int] = None,
        checkpoint_background: Optional[bool] = None,
        step_mode: Optional[str] = None,
        max_workers: Optional[int] = None,
        report_history: Optional[int] = None,
        transport: Optional[Any] = None,
        workers: Optional[int] = None,
        backend_options: Optional[Dict[str, Any]] = None,
        supervise: Union[bool, Dict[str, Any]] = False,
        autoscale: Optional[Union[bool, Dict[str, Any]]] = None,
        on_worker_event: Optional[Hook] = None,
        system: Optional[Any] = None,
        on_merge: Optional[Hook] = None,
        on_unmerge: Optional[Hook] = None,
        on_defrag: Optional[Hook] = None,
        on_step: Optional[Hook] = None,
        on_wave: Optional[Hook] = None,
    ):
        self._hooks: Dict[str, List[Hook]] = {
            "merge": [],
            "unmerge": [],
            "defrag": [],
            "step": [],
            "wave": [],
        }
        if on_merge:
            self._hooks["merge"].append(on_merge)
        if on_unmerge:
            self._hooks["unmerge"].append(on_unmerge)
        if on_defrag:
            self._hooks["defrag"].append(on_defrag)
        if on_step:
            self._hooks["step"].append(on_step)
        if on_wave:
            self._hooks["wave"].append(on_wave)
        self._system = None
        if system is not None:
            # Wrap an existing StreamSystem (the restore() path) — hooks
            # and stepping knobs passed alongside apply to the wrapped
            # planes; checkpoint wiring is the system's own and cannot be
            # changed here (pass it to StreamSystem/restore instead).
            rebind = {
                "checkpoint_dir": checkpoint_dir,
                "checkpoint_every": checkpoint_every,
                "checkpoint_keep_last": checkpoint_keep_last,
                "checkpoint_background": checkpoint_background,
                "transport": transport,
                "workers": workers,
                "backend_options": backend_options,
                "supervise": supervise or None,
                "autoscale": autoscale,
                "on_worker_event": on_worker_event,
            }
            if any(v is not None for v in rebind.values()):
                names = ", ".join(k for k, v in rebind.items() if v is not None)
                raise DataflowError(
                    f"{names} cannot be changed when wrapping an existing "
                    "StreamSystem — configure them on the system (or pass "
                    "them to ReuseSession.restore / StreamSystem.restore)"
                )
            self._system = system
            self.manager = system.manager
            system.backend.configure_stepping(
                step_mode=step_mode,
                max_workers=max_workers,
                on_wave=self._dispatch_wave,
                report_history=report_history,
            )
        elif execute:
            # Deferred import keeps control-plane sessions light; the
            # runtime package itself resolves backends lazily, so a
            # backend="dryrun" session never imports JAX either.
            from repro.runtime.system import StreamSystem

            self._system = StreamSystem(
                strategy=strategy,
                base_batch=base_batch,
                check_invariants=check_invariants,
                journal_path=journal_path,
                backend=backend,
                checkpoint_dir=checkpoint_dir,
                checkpoint_every=checkpoint_every,
                checkpoint_keep_last=checkpoint_keep_last,
                checkpoint_background=bool(checkpoint_background),
                step_mode=step_mode,
                max_workers=max_workers,
                on_wave=self._dispatch_wave,
                report_history=report_history,
                transport=transport,
                workers=workers,
                backend_options=backend_options,
                supervise=supervise,
                autoscale=autoscale,
                on_worker_event=on_worker_event,
            )
            self.manager: ReuseManager = self._system.manager
        else:
            bad = {
                "checkpoint_dir": checkpoint_dir,
                "checkpoint_every": checkpoint_every,
                "checkpoint_keep_last": checkpoint_keep_last,
                "checkpoint_background": checkpoint_background,
                "step_mode": step_mode,
                "max_workers": max_workers,
                "report_history": report_history,
                "transport": transport,
                "workers": workers,
                "backend_options": backend_options,
                "supervise": supervise or None,
                "autoscale": autoscale,
                "on_worker_event": on_worker_event,
            }
            if any(v is not None for v in bad.values()):
                names = ", ".join(k for k, v in bad.items() if v is not None)
                raise DataflowError(
                    f"{names} need a data plane — create the session with "
                    "execute=True (the control plane is journaled via "
                    "journal_path)"
                )
            self.manager = ReuseManager(
                strategy=strategy,
                check_invariants=check_invariants,
                journal_path=journal_path,
            )

    def _dispatch_wave(self, event: WaveEvent) -> None:
        if self._hooks["wave"]:
            self._emit("wave", event)

    # -- construction helpers ------------------------------------------------
    @classmethod
    def restore(cls, path: str, **kwargs: Any) -> "ReuseSession":
        """Rebuild a session from durable state.

        Two flavors, dispatched on what ``path`` holds:

        * a **checkpoint directory** (or one ``ckpt-*.json`` file) — full
          crash recovery: replay the control-plane journal, redeploy every
          data-plane segment on the checkpointed backend (or ``backend=``
          for a cross-backend restore), re-pause, re-attach any
          ``on_merge``/``on_step``/... hooks passed here, and resume
          stepping with trajectories identical to an uninterrupted run.
          The restored session keeps checkpointing into the same directory
          at the checkpointed cadence unless overridden.
        * a **journal file** — the legacy control-plane-only restore
          (``execute=False``).
        """
        import os

        from repro.runtime.checkpoint import is_checkpoint_path

        if os.path.isdir(path) or is_checkpoint_path(path):
            from repro.runtime.system import StreamSystem

            hooks = {
                k: kwargs.pop(k, None)
                for k in ("on_merge", "on_unmerge", "on_defrag", "on_step", "on_wave")
            }
            system = StreamSystem.restore(path, **kwargs)
            return cls(system=system, **{k: v for k, v in hooks.items() if v})
        session = cls(**kwargs)
        if session._system is not None:
            raise DataflowError(
                "restore() from a journal rebuilds the control plane only "
                "(execute=False); restore from a checkpoint directory for the data plane"
            )
        session.manager = ReuseManager.restore(
            path,
            strategy=session.manager._strategy,
            check_invariants=session.manager.check_invariants,
        )
        return session

    def checkpoint(self, checkpoint_dir: Optional[str] = None) -> str:
        """Write one durable full-system checkpoint; returns its path."""
        return self._require_system("checkpoint").checkpoint(checkpoint_dir)

    # -- properties -----------------------------------------------------------
    @property
    def strategy(self) -> str:
        return self.manager.strategy

    @property
    def executes(self) -> bool:
        """True when the session owns a data plane (StreamSystem)."""
        return self._system is not None

    @property
    def backend_name(self) -> Optional[str]:
        """Registry name of the data-plane backend (None for control-plane)."""
        if self._system is None:
            return None
        return self._system.backend.name or type(self._system.backend).__name__

    @property
    def names(self) -> List[str]:
        """Names of currently submitted dataflows."""
        return sorted(self.manager.submitted)

    @property
    def running_task_count(self) -> int:
        return self.manager.running_task_count

    @property
    def submitted_task_count(self) -> int:
        return self.manager.submitted_task_count

    # -- hooks ----------------------------------------------------------------
    def on_merge(self, fn: Hook) -> Hook:
        """Register a merge observer (usable as a decorator)."""
        self._hooks["merge"].append(fn)
        return fn

    def on_unmerge(self, fn: Hook) -> Hook:
        self._hooks["unmerge"].append(fn)
        return fn

    def on_defrag(self, fn: Hook) -> Hook:
        self._hooks["defrag"].append(fn)
        return fn

    def on_step(self, fn: Hook) -> Hook:
        """Register a per-step observer (fires on ``step()`` and ``run()``)."""
        self._hooks["step"].append(fn)
        return fn

    def on_wave(self, fn: Hook) -> Hook:
        """Register a wave observer: one :class:`WaveEvent` per dependency
        wave per step (which segments stepped together, and the wave's
        contribution to the step makespan)."""
        self._hooks["wave"].append(fn)
        return fn

    def _emit(self, kind: str, event: Any) -> None:
        for fn in self._hooks[kind]:
            fn(event)

    # -- operations -----------------------------------------------------------
    def submit(self, df: Submittable) -> SubmissionReceipt:
        """Submit one dataflow (builder or Dataflow) — merge per §4.1."""
        dataflow = as_dataflow(df)
        target = self._system if self._system is not None else self.manager
        receipt = target.submit(dataflow)
        self._emit(
            "merge",
            MergeEvent(
                name=receipt.name,
                running_dag=receipt.running_dag,
                num_reused=receipt.num_reused,
                num_created=receipt.num_created,
                batched=False,
                receipt=receipt,
            ),
        )
        return receipt

    def preview(self, df: Submittable, validate: bool = True):
        """Plan a submission without committing it (admission control).

        Returns the :class:`~repro.core.merge.MergePlan` the next
        :meth:`submit` of this dataflow would enact against the current
        running set — ``plan.num_created`` is the number of new running
        tasks, which is what slot-based admission charges. The session
        (control plane *and* data plane) is left untouched.
        """
        return self.manager.preview(as_dataflow(df), validate=validate)

    def submit_many(self, dfs: Iterable[Submittable]) -> BatchSubmitReceipt:
        """Submit a batch with batch-aware planning (one signature pass and
        one merged-DAG rebuild per overlapping group — see
        :meth:`repro.core.manager.ReuseManager.submit_many`)."""
        dataflows = [as_dataflow(df) for df in dfs]
        target = self._system if self._system is not None else self.manager
        receipts = target.submit_many(dataflows)
        for receipt in receipts:
            self._emit(
                "merge",
                MergeEvent(
                    name=receipt.name,
                    running_dag=receipt.running_dag,
                    num_reused=receipt.num_reused,
                    num_created=receipt.num_created,
                    batched=True,
                    receipt=receipt,
                ),
            )
        return BatchSubmitReceipt(receipts=tuple(receipts))

    def remove(self, name: str) -> RemovalReceipt:
        """Remove a submission — unmerge per §4.2."""
        target = self._system if self._system is not None else self.manager
        receipt = target.remove(name)
        self._emit(
            "unmerge",
            UnmergeEvent(
                name=receipt.name,
                terminated_tasks=set(receipt.terminated_tasks),
                surviving_dags=list(receipt.surviving_dags),
                receipt=receipt,
            ),
        )
        return receipt

    def defragment(self) -> DefragEvent:
        """Relaunch fused segments (state-preserving defrag; data plane only)."""
        system = self._require_system("defragment")
        killed = system.defragment()
        event = DefragEvent(
            segments_killed=killed,
            segments_after=len(system.backend.segments),
            deployed_tasks_after=system.deployed_task_count,
        )
        self._emit("defrag", event)
        return event

    def fuse(self, min_length: int = 2, overhead_ms: float = 0.25) -> Dict[str, List[str]]:
        """Fuse linear same-DAG segment chains into single compiled segments.

        The depth-only sibling of :meth:`defragment`: private segment-to-
        segment pipes collapse into one donated-buffer jitted step, while
        parallel waves and paused residue stay untouched. Candidate chains
        are scored against the dry-run latency model first (wave-aware
        planning — see :attr:`fusion_report` for every accept/reject), and
        accepted cross-worker chains are migrated to one worker before
        recompiling. Returns ``{fused segment name: [member names
        replaced]}``.
        """
        return self._require_system("fuse").fuse(
            min_length=min_length, overhead_ms=overhead_ms
        )

    @property
    def fusion_report(self):
        """The last :meth:`fuse` call's planner verdicts
        (:class:`repro.core.defrag.FusionReport`), or ``None``."""
        return self._system.fusion_report if self._system is not None else None

    # -- execution -------------------------------------------------------------
    def step(self):
        report = self._require_system("step").step()
        self._emit_step(report)
        return report

    def run(self, steps: int):
        system = self._require_system("run")
        reports = []
        for _ in range(steps):
            report = system.step()
            self._emit_step(report)
            reports.append(report)
        return reports

    def _emit_step(self, report: Any) -> None:
        if not self._hooks["step"]:
            return
        self._emit(
            "step",
            StepEvent(
                step=report.step,
                live_tasks=report.live_tasks,
                paused_tasks=report.paused_tasks,
                cost=report.cost,
                wall_ms=report.wall_ms,
                report=report,
            ),
        )

    def sink_digests(self, name: str) -> Dict[str, Dict[str, Any]]:
        """Per-sink count/checksum for a submission (output identity check)."""
        return self._require_system("sink_digests").sink_digests(name)

    def quiesce(self) -> None:
        """Drain in-flight data-plane work (concurrent dispatch, queued
        background checkpoints) without releasing anything — see
        :meth:`repro.runtime.system.StreamSystem.quiesce`."""
        self._require_system("quiesce").quiesce()

    def close(self) -> None:
        """Release data-plane resources (the concurrent dispatch pool).

        Idempotent and non-destructive — control-plane state survives and
        stepping after close() re-creates the pool lazily."""
        if self._system is not None:
            self._system.close()

    def __enter__(self) -> "ReuseSession":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()

    def _require_system(self, op: str):
        if self._system is None:
            raise DataflowError(
                f"{op}() needs a data plane — create the session with execute=True"
            )
        return self._system

    # -- observability -----------------------------------------------------------
    def verify(self) -> None:
        """Check the §3.3 system invariants (C1 sink coverage, C2 minimization)."""
        self.manager.verify()

    def reuse_counts(self) -> Dict[str, int]:
        return self.manager.reuse_counts()

    def worker_health(self) -> Optional[Dict[str, Any]]:
        """Cluster-plane health snapshot (worker liveness, respawns,
        staleness marking, autoscaler state). ``None`` for control-plane
        sessions and in-process backends — only a worker-pool backend can
        be sick."""
        if self._system is None:
            return None
        return self._system.worker_health()

    # -- telemetry plane (repro.obs) -------------------------------------------
    def configure_obs(
        self,
        metrics: Optional[bool] = None,
        trace: Optional[bool] = None,
        sample_stride: Optional[int] = None,
        trace_capacity: Optional[int] = None,
    ) -> "ReuseSession":
        """Turn the metrics registry and/or span tracing on or off.

        ``trace=True`` arms step-span tracing on every layer (wave
        dispatch, per-segment steps, transport, worker RPCs, compile
        misses, merge/unmerge, checkpoints); ``sample_stride=N`` records
        every Nth span per name. ``metrics=False`` swaps in a null
        registry for overhead-sensitive runs. Needs a data plane.
        """
        self._require_system("configure_obs").configure_obs(
            metrics=metrics,
            trace=trace,
            sample_stride=sample_stride,
            trace_capacity=trace_capacity,
        )
        return self

    def enable_tracing(self, sample_stride: int = 1) -> "ReuseSession":
        """Shorthand for ``configure_obs(trace=True, sample_stride=...)``."""
        return self.configure_obs(trace=True, sample_stride=sample_stride)

    def metrics_snapshot(self) -> Dict[str, Any]:
        """Merged metrics snapshot (coordinator + multiproc workers) —
        counters, gauges and histograms as plain JSON-safe dicts."""
        return self._require_system("metrics_snapshot").metrics_snapshot()

    def prometheus_text(self) -> str:
        """The merged snapshot as Prometheus text exposition 0.0.4 — what
        the serving front end's ``/metrics`` endpoint returns."""
        return self._require_system("prometheus_text").prometheus_text()

    def drain_spans(self) -> List[Dict[str, Any]]:
        """Drain buffered trace spans (destructive), sorted by start time."""
        return self._require_system("drain_spans").drain_spans()

    def export_chrome_trace(self, path: str) -> int:
        """Drain spans into a Chrome/Perfetto-loadable trace file; returns
        the number of spans written."""
        return self._require_system("export_chrome_trace").export_chrome_trace(path)

    def segment_latency_ms(self) -> Dict[str, Dict[str, float]]:
        """Canonical per-segment step-latency digest (mean/last/max/samples
        in ms) — the same measured samples the fusion calibrator consumes;
        see :meth:`repro.runtime.system.StreamSystem.segment_latency_ms`."""
        return self._require_system("segment_latency_ms").segment_latency_ms()

    def stats(self) -> SessionStats:
        mgr = self.manager
        hist = Counter(mgr.reuse_counts().values()) if mgr.running else Counter()
        deployed = segments = steps = 0
        cache = {"hits": 0, "misses": 0, "evictions": 0, "entries": 0}
        if self._system is not None:
            deployed = self._system.deployed_task_count
            segments = len(self._system.backend.segments)
            steps = self._system.backend.step_count
            cache = self._system.backend.compile_cache_stats()
        return SessionStats(
            strategy=self.strategy,
            submitted_dataflows=len(mgr.submitted),
            running_dataflows=len(mgr.running),
            submitted_task_count=mgr.submitted_task_count,
            running_task_count=mgr.running_task_count,
            reuse_histogram=dict(hist),
            deployed_task_count=deployed,
            segments=segments,
            steps_run=steps,
            backend=self.backend_name,
            compile_cache_hits=cache.get("hits", 0),
            compile_cache_misses=cache.get("misses", 0),
            compile_cache_evictions=cache.get("evictions", 0),
            compile_cache_entries=cache.get("entries", 0),
        )

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        plane = f"data[{self.backend_name}]" if self.executes else "control"
        return (
            f"ReuseSession(strategy={self.strategy!r}, plane={plane}, "
            f"submitted={len(self.manager.submitted)}, running_tasks={self.running_task_count})"
        )
