"""`repro.api` — the single public surface of the reproduction.

The paper's Reusable Dataflow Manager (§4.3) is one control-plane entry
point for a growing ecosystem of collaborating IoT applications. This
package is that entry point for library users:

  * :func:`flow` / :class:`DataflowBuilder` — fluent construction of
    validated de-dup :class:`~repro.core.graph.Dataflow` DAGs::

        df = (flow("alice")
              .source("urban")
              .then("senml_parse", schema="urban")
              .then("kalman", q=0.1)
              .sink("store")
              .build())

  * :class:`ReuseSession` — owns a control-plane
    :class:`~repro.core.manager.ReuseManager` (or, with ``execute=True``,
    a full :class:`~repro.runtime.system.StreamSystem` data plane) and
    exposes ``submit / submit_many / remove / defragment / run / stats``
    plus ``on_merge / on_unmerge / on_defrag`` observability hooks.

  * the pluggable equivalence-strategy registry
    (:func:`register_strategy`, :func:`available_strategies`,
    :class:`MergeStrategy`) — new engines plug in without editing the
    manager;

  * the pluggable execution-backend registry
    (:func:`register_backend`, :func:`available_backends`,
    :class:`ExecutionBackend`) — the data plane behind
    ``ReuseSession(execute=True, backend=...)``: ``"inprocess"`` jit,
    ``"sharded"`` multi-device, ``"dryrun"`` pure cost model.

Import stays light: the JAX data plane only loads when a session is
created with ``execute=True`` on a jit backend — ``backend="dryrun"``
never imports JAX at all.
"""
from repro.core import DataflowError
from repro.core.graph import Dataflow, Task
from repro.core.manager import RemovalReceipt, SubmissionReceipt
from repro.core.strategies import (
    MergeStrategy,
    available_strategies,
    register_strategy,
    resolve_strategy,
)
from repro.runtime.backend import (
    ExecutionBackend,
    StepReport,
    available_backends,
    register_backend,
    resolve_backend,
)
from repro.runtime.transport import (
    Transport,
    available_transports,
    register_transport,
    resolve_transport,
)
from repro.runtime.checkpoint import (
    CHECKPOINT_FORMAT_VERSION,
    CheckpointError,
    CheckpointStore,
)

from .builder import DataflowBuilder, flow
from .events import (
    BatchSubmitReceipt,
    DefragEvent,
    MergeEvent,
    SessionStats,
    StepEvent,
    UnmergeEvent,
    WaveEvent,
)
from .session import ReuseSession

__all__ = [
    "BatchSubmitReceipt",
    "CHECKPOINT_FORMAT_VERSION",
    "CheckpointError",
    "CheckpointStore",
    "Dataflow",
    "DataflowBuilder",
    "DataflowError",
    "DefragEvent",
    "ExecutionBackend",
    "MergeEvent",
    "MergeStrategy",
    "RemovalReceipt",
    "ReuseSession",
    "SessionStats",
    "StepEvent",
    "StepReport",
    "SubmissionReceipt",
    "Task",
    "UnmergeEvent",
    "WaveEvent",
    "available_backends",
    "available_strategies",
    "available_transports",
    "flow",
    "Transport",
    "register_backend",
    "register_strategy",
    "register_transport",
    "resolve_backend",
    "resolve_transport",
    "resolve_strategy",
]
