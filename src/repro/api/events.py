"""Typed receipts and lifecycle events surfaced by :class:`ReuseSession`.

Submissions already return :class:`~repro.core.manager.SubmissionReceipt` /
:class:`~repro.core.manager.RemovalReceipt`; this module adds the
session-level aggregates (batch receipt, stats snapshot) and the event
objects delivered to ``on_merge`` / ``on_unmerge`` / ``on_defrag`` hooks.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Set, Tuple

from repro.core.manager import RemovalReceipt, SubmissionReceipt

# The wave event is minted where waves are scheduled (JAX-free module);
# re-exported here so session users import every event type from one place.
from repro.runtime.scheduler import WaveEvent

__all__ = [
    "BatchSubmitReceipt",
    "DefragEvent",
    "MergeEvent",
    "SessionStats",
    "StepEvent",
    "UnmergeEvent",
    "WaveEvent",
]


@dataclass(frozen=True)
class MergeEvent:
    """Fired after a submission merged into the running set (§4.1)."""

    name: str
    running_dag: str
    num_reused: int
    num_created: int
    batched: bool  # True when part of a submit_many batch
    receipt: SubmissionReceipt


@dataclass(frozen=True)
class UnmergeEvent:
    """Fired after a removal unmerged the running set (§4.2)."""

    name: str
    terminated_tasks: Set[str]
    surviving_dags: List[str]
    receipt: RemovalReceipt


@dataclass(frozen=True)
class DefragEvent:
    """Fired after a data-plane defragmentation pass."""

    segments_killed: int
    segments_after: int
    deployed_tasks_after: int


@dataclass(frozen=True)
class StepEvent:
    """Fired after every data-plane step (any backend) — the Fig. 2/3 counters."""

    step: int
    live_tasks: int
    paused_tasks: int
    cost: float  # core-equivalents this step
    wall_ms: float
    report: Any  # the backend's full StepReport

    @property
    def makespan_ms(self) -> float:
        """Dependency-DAG modelled step latency (wave max in concurrent mode)."""
        return self.report.makespan_ms


@dataclass(frozen=True)
class BatchSubmitReceipt:
    """Aggregate receipt for :meth:`ReuseSession.submit_many`."""

    receipts: Tuple[SubmissionReceipt, ...]

    def __iter__(self):
        return iter(self.receipts)

    def __len__(self) -> int:
        return len(self.receipts)

    def __getitem__(self, i: int) -> SubmissionReceipt:
        return self.receipts[i]

    @property
    def names(self) -> List[str]:
        return [r.name for r in self.receipts]

    @property
    def num_reused(self) -> int:
        return sum(r.num_reused for r in self.receipts)

    @property
    def num_created(self) -> int:
        return sum(r.num_created for r in self.receipts)

    @property
    def running_dags(self) -> List[str]:
        return sorted({r.running_dag for r in self.receipts})


@dataclass(frozen=True)
class SessionStats:
    """Point-in-time snapshot of a session (the paper's Fig. 2 metrics)."""

    strategy: str
    submitted_dataflows: int
    running_dataflows: int
    submitted_task_count: int
    running_task_count: int
    reuse_histogram: Dict[int, int] = field(default_factory=dict)
    # data-plane extras (0/None when the session is control-plane only)
    deployed_task_count: int = 0
    segments: int = 0
    steps_run: int = 0
    backend: Any = None  # ExecutionBackend registry name
    # compiled-segment reuse cache counters (collaborative reuse at the
    # XLA-executable level; zeros for backends that never compile)
    compile_cache_hits: int = 0
    compile_cache_misses: int = 0
    compile_cache_evictions: int = 0
    compile_cache_entries: int = 0

    @property
    def task_reduction(self) -> float:
        """1 − running/submitted — the headline saving (Fig. 2)."""
        if self.submitted_task_count == 0:
            return 0.0
        return 1.0 - self.running_task_count / self.submitted_task_count
