"""Fluent construction of validated de-dup dataflows.

The paper's client API (§3.1) takes a DAG of concrete tasks; hand-wiring
``Task.make`` + ``add_stream`` is verbose and easy to get structurally
wrong (dangling leaves, duplicate equivalence classes). The builder keeps
a *cursor* — each ``then`` appends downstream of the previous step — and
supports branches and fan-ins through labels:

    df = (flow("stats")
          .source("urban")
          .then("senml_parse", schema="urban", label="parse")
          .then("win", w=16, label="w")
          .then("avg")                       # branch 1 continues from win
          .sink("store")
          .at("w")                           # move cursor back to win
          .then("moment2")                   # branch 2 off the window op
          .sink("store")
          .build())

Fan-in: ``then("join", after=["a", "b"])`` wires both labelled steps into
the new task. ``build()`` coalesces any structurally equivalent duplicate
steps (same Merkle signature — paper §3.2) and validates, so every built
dataflow is submission-ready.
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple, Union

from repro.core.graph import SINK_CONFIG, SOURCE_CONFIG, Dataflow, DataflowError, Task
from repro.core.signatures import dedup_fast

After = Union[str, Sequence[str], None]


class DataflowBuilder:
    """Fluent builder; every step method returns ``self`` for chaining."""

    def __init__(self, name: str):
        if not name:
            raise DataflowError("dataflow name must be non-empty")
        self.name = name
        self._tasks: List[Task] = []
        self._streams: List[Tuple[str, str]] = []
        self._labels: Dict[str, str] = {}  # label -> task id
        self._cursor: Optional[str] = None
        self._counter = 0

    # -- step methods -------------------------------------------------------
    def source(self, source_type: str, *, label: Optional[str] = None) -> "DataflowBuilder":
        """Add a source task (abstractly identified by its type — §3.1)."""
        return self._add(source_type, SOURCE_CONFIG, label=label, after=())

    def then(
        self,
        task_type: str,
        *,
        label: Optional[str] = None,
        after: After = None,
        **config: Any,
    ) -> "DataflowBuilder":
        """Append a task downstream of the cursor (or of ``after`` labels)."""
        return self._add(task_type, config, label=label, after=after)

    def sink(
        self,
        sink_type: str = "store",
        *,
        label: Optional[str] = None,
        after: After = None,
    ) -> "DataflowBuilder":
        """Terminate the current chain in a sink task."""
        return self._add(sink_type, SINK_CONFIG, label=label, after=after)

    def at(self, label: str) -> "DataflowBuilder":
        """Move the cursor to a labelled step (start of a branch)."""
        self._cursor = self._resolve(label)
        return self

    branch = at  # readability alias: .branch("w").then(...)

    # -- compilation --------------------------------------------------------
    def build(self, validate: bool = True) -> Dataflow:
        """Compile to a :class:`Dataflow`; validated and de-dup by construction.

        Structurally equivalent duplicate steps (equal type, config and
        ancestry) are coalesced — the §3.2 de-dup transform — and the
        submission contract is enforced eagerly (every chain must terminate
        in a sink — §3.3 C2), so a built dataflow is submission-ready.
        """
        df = Dataflow(self.name, self._tasks, self._streams)
        df = dedup_fast(df)
        if validate:
            df.validate()
            for tid, t in df.tasks.items():
                if not t.is_sink and not df.children(tid):
                    raise DataflowError(
                        f"step {tid!r} dangles — every chain in flow {self.name!r} "
                        f"must end with .sink() (paper §3.3 C2)"
                    )
        return df

    # -- internals ----------------------------------------------------------
    def _resolve(self, label: str) -> str:
        if label not in self._labels:
            raise DataflowError(
                f"unknown label {label!r} in flow {self.name!r} "
                f"(known: {', '.join(sorted(self._labels)) or 'none'})"
            )
        return self._labels[label]

    def _parents(self, after: After) -> List[str]:
        if after is None:
            if self._cursor is None:
                raise DataflowError(
                    f"flow {self.name!r} has no upstream step yet — start with .source()"
                )
            return [self._cursor]
        if isinstance(after, str):
            return [self._resolve(after)]
        return [self._resolve(a) for a in after]

    def _add(
        self,
        task_type: str,
        config: Any,
        *,
        label: Optional[str],
        after: After,
    ) -> "DataflowBuilder":
        parents = self._parents(after) if after != () else []
        tid = f"{self.name}/{self._counter}.{task_type}"
        self._counter += 1
        task = Task.make(tid, task_type, config)
        self._tasks.append(task)
        for p in parents:
            self._streams.append((p, tid))
        if label is not None:
            if label in self._labels:
                raise DataflowError(f"duplicate label {label!r} in flow {self.name!r}")
            self._labels[label] = tid
        self._cursor = tid
        return self

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"DataflowBuilder({self.name!r}, steps={len(self._tasks)})"


def flow(name: str) -> DataflowBuilder:
    """Start a fluent dataflow definition: ``flow("alice").source(...)…``"""
    return DataflowBuilder(name)


def as_dataflow(obj: Union[Dataflow, DataflowBuilder]) -> Dataflow:
    """Accept either a built Dataflow or a builder (session entry points)."""
    if isinstance(obj, DataflowBuilder):
        return obj.build()
    if isinstance(obj, Dataflow):
        return obj
    raise TypeError(f"expected Dataflow or DataflowBuilder, got {type(obj).__name__}")
