"""Batched serving engine: slot-based continuous batching over the model
zoo's prefill/decode paths.

A fixed pool of ``slots`` (the decode batch) runs one jitted decode step
per tick; finished/empty slots are refilled from the request queue via a
fresh prefill whose cache row is spliced into the pool. Greedy or
temperature sampling. The engine is deliberately mesh-agnostic — under a
mesh the same jitted steps run SPMD (launch/serve.py wires that).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, init_cache, prefill
from repro.models.config import ModelConfig

PyTree = Any


@dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (S,) int32
    max_new: int = 16
    temperature: float = 0.0    # 0 = greedy
    memory: Optional[np.ndarray] = None


@dataclass
class GenerationResult:
    rid: int
    tokens: List[int]
    prompt_len: int


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params: PyTree, *, slots: int = 4, max_len: int = 512):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        mem_len = {"vlm": cfg.num_image_tokens, "audio": cfg.encoder_seq}.get(cfg.family, 0)
        self.mem_len = mem_len
        self._queue: List[Request] = []
        self._active: Dict[int, Request] = {}        # slot -> request
        self._generated: Dict[int, List[int]] = {}
        self._done: List[GenerationResult] = []
        self._budget: Dict[int, int] = {}

        # one cache per slot (batch=1) — spliceable without reshaping
        self._caches: List[PyTree] = [
            init_cache(cfg, 1, max_len, memory_len=mem_len) for _ in range(slots)
        ]
        self._next_tok = np.zeros((slots, 1), np.int32)
        self._live = np.zeros((slots,), bool)

        self._prefill = jax.jit(
            lambda p, t, c, m: prefill(p, cfg, t, c, memory=m)
            if mem_len
            else prefill(p, cfg, t, c)
        ) if mem_len else jax.jit(lambda p, t, c: prefill(p, cfg, t, c))
        self._decode = jax.jit(lambda p, t, c: decode_step(p, cfg, t, c))

    # -- public API -----------------------------------------------------------
    def submit(self, req: Request) -> None:
        self._queue.append(req)

    def run(self, max_ticks: int = 1000) -> List[GenerationResult]:
        ticks = 0
        while (self._queue or self._live.any()) and ticks < max_ticks:
            self.tick()
            ticks += 1
        return self.results()

    def results(self) -> List[GenerationResult]:
        out, self._done = self._done, []
        return out

    # -- engine internals ------------------------------------------------------
    def tick(self) -> None:
        self._fill_slots()
        if not self._live.any():
            return
        for s in np.nonzero(self._live)[0]:
            tok = jnp.asarray(self._next_tok[s : s + 1])
            logits, self._caches[s] = self._decode(self.params, tok, self._caches[s])
            nxt = self._sample(logits, self._active[s].temperature)
            self._push_token(int(s), int(nxt))

    def _fill_slots(self) -> None:
        for s in range(self.slots):
            if self._live[s] or not self._queue:
                continue
            req = self._queue.pop(0)
            cache = init_cache(self.cfg, 1, self.max_len, memory_len=self.mem_len)
            toks = jnp.asarray(req.prompt[None, :], jnp.int32)
            if self.mem_len:
                mem = jnp.asarray(req.memory[None], jnp.float32)
                logits, cache = self._prefill(self.params, toks, cache, mem)
            else:
                logits, cache = self._prefill(self.params, toks, cache)
            self._caches[s] = cache
            nxt = self._sample(logits, req.temperature)
            self._active[s] = req
            self._generated[s] = []
            self._budget[s] = req.max_new
            self._live[s] = True
            self._push_token(s, int(nxt))

    def _push_token(self, slot: int, tok: int) -> None:
        self._generated[slot].append(tok)
        self._next_tok[slot, 0] = tok
        if len(self._generated[slot]) >= self._budget[slot]:
            req = self._active.pop(slot)
            self._done.append(
                GenerationResult(req.rid, self._generated.pop(slot), len(req.prompt))
            )
            self._live[slot] = False

    @staticmethod
    def _sample(logits: jnp.ndarray, temperature: float) -> int:
        if temperature <= 0:
            return int(jnp.argmax(logits[0]))
        key = jax.random.PRNGKey(int(jnp.sum(jnp.abs(logits)) * 1e3) % (2**31))
        return int(jax.random.categorical(key, logits[0] / temperature))
