"""ServeFrontend — a long-running multi-tenant dataflow server.

The paper's pitch is that collaborative reuse multiplies effective
capacity: a submission that merges into already-running dataflows only
needs resources for its *new* segments. This module turns that into an
admission-control policy. The frontend wraps one
:class:`~repro.api.ReuseSession` behind a bounded **slot pool** — one slot
per newly-created running task — so a fully-reused submission costs zero
slots and is always admissible, while a cold submission pays full freight.

Admission of ``submit(tenant, df)``:

1. ``session.preview(df)`` plans the merge without committing — a pure
   read of the running set, so the quoted cost (``plan.num_created``) is
   exactly what a real submit would charge *right now*.
2. cost > tenant ``max_slots`` or > the whole pool → ``REJECTED`` (it can
   never fit).
3. cost ≤ free slots and nothing is queued ahead → submit for real,
   charge ``receipt.num_created`` slots → ``ADMITTED``.
4. otherwise queue it if the tenant has pending headroom → ``QUEUED``;
   else → ``RETRY_AFTER`` with a resubmit hint.

Queued submissions drain in **weighted fair-share** order (stride
scheduling): each tenant accrues virtual time ``vtime += slots_charged /
weight`` as its work is admitted, and the pending submission of the
lowest-vtime tenant that *fits* goes first — a greedy tenant cannot starve
a light one, and zero-cost (fully reused) submissions never block.

Per-tenant ledgers track slots held, slots saved by reuse (the cost a
no-reuse plan would have charged), and cumulative core-equivalent cost
billed from the backend ``account`` verb (shared tasks split their cost
evenly among the submissions using them). Ledgers persist across
checkpoint/restore via a JSON sidecar written atomically next to the
session's checkpoints.

The frontend is also a socket server (``start()``), speaking the framed
JSON protocol in :mod:`repro.serve.protocol` over the tcp transport's
wire machinery; :class:`repro.serve.client.ServeClient` is the matching
blocking client. Everything here is JAX-free with ``backend="dryrun"``.
"""
from __future__ import annotations

import json
import logging
import os
import socket
import threading
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple, Union

from repro.core import DataflowError
from repro.core.graph import Dataflow

from . import protocol

logger = logging.getLogger(__name__)

_LEDGER_FILE = "frontend-ledger.json"


@dataclass(frozen=True)
class TenantQuota:
    """Per-tenant admission limits.

    ``max_slots`` caps the slots a tenant may hold at once; ``max_pending``
    caps its admission queue; ``weight`` scales its fair share (a weight-2
    tenant accrues virtual time half as fast, so it drains twice as often
    under contention).
    """

    max_slots: int = 64
    max_pending: int = 16
    weight: float = 1.0

    def to_json(self) -> Dict[str, Any]:
        return {
            "max_slots": self.max_slots,
            "max_pending": self.max_pending,
            "weight": self.weight,
        }

    @classmethod
    def from_json(cls, obj: Dict[str, Any]) -> "TenantQuota":
        return cls(
            max_slots=int(obj["max_slots"]),
            max_pending=int(obj["max_pending"]),
            weight=float(obj["weight"]),
        )


@dataclass
class TenantLedger:
    """Cumulative per-tenant accounting, persisted across restore."""

    tenant: str
    slots_held: int = 0
    slots_saved: int = 0  # Σ (submission size - slots charged): reuse dividend
    submitted: int = 0  # submit() calls seen (any outcome)
    admitted: int = 0
    rejected: int = 0
    backpressured: int = 0  # RETRY_AFTER responses (not terminal rejections)
    removed: int = 0
    cost_total: float = 0.0  # core-equivalent·steps billed to this tenant
    vtime: float = 0.0  # fair-share virtual time (slots/weight)
    dataflows: Dict[str, int] = field(default_factory=dict)  # name -> slots charged

    def to_json(self) -> Dict[str, Any]:
        return {
            "tenant": self.tenant,
            "slots_held": self.slots_held,
            "slots_saved": self.slots_saved,
            "submitted": self.submitted,
            "admitted": self.admitted,
            "rejected": self.rejected,
            "backpressured": self.backpressured,
            "removed": self.removed,
            "cost_total": self.cost_total,
            "vtime": self.vtime,
            "dataflows": dict(self.dataflows),
        }

    @classmethod
    def from_json(cls, obj: Dict[str, Any]) -> "TenantLedger":
        return cls(
            tenant=obj["tenant"],
            slots_held=int(obj["slots_held"]),
            slots_saved=int(obj["slots_saved"]),
            submitted=int(obj["submitted"]),
            admitted=int(obj["admitted"]),
            rejected=int(obj["rejected"]),
            backpressured=int(obj.get("backpressured", 0)),
            removed=int(obj["removed"]),
            cost_total=float(obj["cost_total"]),
            vtime=float(obj["vtime"]),
            dataflows={k: int(v) for k, v in obj["dataflows"].items()},
        )


@dataclass(frozen=True)
class AdmissionResult:
    """Outcome of one submit — mirrors the wire response."""

    status: str  # protocol.ADMITTED / QUEUED / RETRY_AFTER / REJECTED
    name: str
    tenant: str
    slots_charged: int = 0
    reused: int = 0
    created: int = 0
    reason: str = ""
    retry_after: float = 0.0

    def to_json(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {
            "ok": True,
            "status": self.status,
            "name": self.name,
            "tenant": self.tenant,
        }
        if self.status == protocol.ADMITTED:
            out.update(
                slots_charged=self.slots_charged,
                reused=self.reused,
                created=self.created,
            )
        if self.reason:
            out["reason"] = self.reason
        if self.status == protocol.RETRY_AFTER:
            out["retry_after"] = self.retry_after
        return out


@dataclass(frozen=True)
class _Pending:
    tenant: str
    df: Dataflow
    seq: int  # arrival order, the fair-share tie-break


class ServeFrontend:
    """Multi-tenant serving daemon over one :class:`ReuseSession`.

    Usable purely in-process (call :meth:`submit` / :meth:`remove` /
    :meth:`step` directly) or as a socket server (:meth:`start` +
    :meth:`serve_forever`). All session-touching entry points serialize on
    one reentrant lock, so wire handlers and in-process callers compose.
    """

    def __init__(
        self,
        *,
        slots: int = 256,
        strategy: str = "signature",
        backend: str = "dryrun",
        default_quota: Optional[TenantQuota] = None,
        quotas: Optional[Dict[str, TenantQuota]] = None,
        retry_after: float = 0.5,
        host: str = "127.0.0.1",
        port: int = 0,
        conn_timeout: float = 5.0,
        checkpoint_dir: Optional[str] = None,
        checkpoint_every: Optional[int] = None,
        defrag_every: Optional[int] = None,
        metrics_port: Optional[int] = None,
        session: Optional[Any] = None,
        **session_kwargs: Any,
    ):
        if slots < 1:
            raise ValueError("slots must be >= 1")
        if session is not None:
            self.session = session
        else:
            from repro.api import ReuseSession

            self.session = ReuseSession(
                strategy=strategy,
                execute=True,
                backend=backend,
                checkpoint_dir=checkpoint_dir,
                checkpoint_every=checkpoint_every,
                **session_kwargs,
            )
        self.slots = slots
        self.default_quota = default_quota or TenantQuota()
        self.quotas: Dict[str, TenantQuota] = dict(quotas or {})
        self.retry_after = retry_after
        self.defrag_every = defrag_every
        self.host = host
        self.port = port
        self.conn_timeout = conn_timeout

        self._lock = threading.RLock()
        self.ledgers: Dict[str, TenantLedger] = {}
        self.tenant_of: Dict[str, str] = {}  # admitted dataflow name -> tenant
        self.naive_of: Dict[str, int] = {}  # admitted name -> task count (no-reuse cost)
        self._pending: List[_Pending] = []
        self._seq = 0
        self.slots_used = 0
        self.naive_slots = 0  # what a reuse-disabled pool would be holding
        self.steps = 0
        self._removes_since_defrag = 0
        self.draining = False

        # telemetry plane: serve-level gauges ride the session backend's
        # registry via a scrape-time collector; metrics_port (not None)
        # additionally serves plain-HTTP GET /metrics for Prometheus
        # scrapers that don't speak the framed JSON protocol (0 = ephemeral)
        self.metrics_port = metrics_port
        self._obs_registry: Optional[Any] = None
        self._metrics_sock: Optional[socket.socket] = None
        self._metrics_thread: Optional[threading.Thread] = None
        self._wire_serve_obs()

        # socket plumbing
        self._sock: Optional[socket.socket] = None
        self._accept_thread: Optional[threading.Thread] = None
        self._conn_threads: List[threading.Thread] = []
        self._conns: set = set()
        self._conns_lock = threading.Lock()
        self._closed = False
        self._shutdown_event = threading.Event()
        # set by the conn loop once a SHUTDOWN reply is on the wire, so the
        # stop thread doesn't close the socket under the in-flight response
        self._stop_ack: Optional[threading.Event] = None

    # -- quota / ledger helpers ------------------------------------------------
    def quota_for(self, tenant: str) -> TenantQuota:
        return self.quotas.get(tenant, self.default_quota)

    def ledger_for(self, tenant: str) -> TenantLedger:
        ledger = self.ledgers.get(tenant)
        if ledger is None:
            ledger = self.ledgers[tenant] = TenantLedger(tenant=tenant)
        return ledger

    @property
    def slots_free(self) -> int:
        return self.slots - self.slots_used

    def _pending_of(self, tenant: str) -> int:
        return sum(1 for p in self._pending if p.tenant == tenant)

    # -- admission -------------------------------------------------------------
    def submit(self, tenant: str, df: Union[Dataflow, Any]) -> AdmissionResult:
        """Admit, queue, backpressure or reject one submission (see module
        docstring for the decision ladder)."""
        from repro.api.builder import as_dataflow

        df = as_dataflow(df)
        with self._lock:
            ledger = self.ledger_for(tenant)
            ledger.submitted += 1
            if self.draining:
                ledger.rejected += 1
                return AdmissionResult(
                    status=protocol.REJECTED,
                    name=df.name,
                    tenant=tenant,
                    reason="server is draining",
                )
            if df.name in self.tenant_of or any(
                p.df.name == df.name for p in self._pending
            ):
                ledger.rejected += 1
                return AdmissionResult(
                    status=protocol.REJECTED,
                    name=df.name,
                    tenant=tenant,
                    reason=f"dataflow {df.name!r} already submitted",
                )
            quota = self.quota_for(tenant)
            try:
                cost = self.session.preview(df).num_created
            except DataflowError as e:
                ledger.rejected += 1
                return AdmissionResult(
                    status=protocol.REJECTED,
                    name=df.name,
                    tenant=tenant,
                    reason=str(e),
                )
            if cost > self.slots:
                ledger.rejected += 1
                return AdmissionResult(
                    status=protocol.REJECTED,
                    name=df.name,
                    tenant=tenant,
                    reason=f"cost {cost} exceeds the slot pool ({self.slots})",
                )
            if ledger.slots_held + cost > quota.max_slots:
                ledger.rejected += 1
                return AdmissionResult(
                    status=protocol.REJECTED,
                    name=df.name,
                    tenant=tenant,
                    reason=(
                        f"cost {cost} would exceed tenant quota "
                        f"({ledger.slots_held}/{quota.max_slots} slots held)"
                    ),
                )
            # Admit immediately only when nothing is queued — otherwise a
            # late cheap submission would jump the fair-share queue.
            if not self._pending and cost <= self.slots_free:
                return self._admit(tenant, df)
            if self._pending_of(tenant) < quota.max_pending:
                self._pending.append(_Pending(tenant=tenant, df=df, seq=self._seq))
                self._seq += 1
                # A queued cheap submission may fit even while the head
                # blocks — but only via the fair-share pass, never LIFO.
                admitted = self._drain_pending()
                for result in admitted:
                    if result.name == df.name:
                        return result
                return AdmissionResult(
                    status=protocol.QUEUED, name=df.name, tenant=tenant
                )
            ledger.backpressured += 1
            return AdmissionResult(
                status=protocol.RETRY_AFTER,
                name=df.name,
                tenant=tenant,
                reason=(
                    f"slot pool saturated ({self.slots_used}/{self.slots}) and "
                    f"tenant queue full ({quota.max_pending} pending)"
                ),
                retry_after=self.retry_after,
            )

    def _admit(self, tenant: str, df: Dataflow) -> AdmissionResult:
        """Commit one submission and charge the tenant. Lock held."""
        receipt = self.session.submit(df)
        charged = receipt.num_created
        ledger = self.ledger_for(tenant)
        ledger.admitted += 1
        ledger.slots_held += charged
        ledger.slots_saved += receipt.num_reused
        ledger.vtime += charged / self.quota_for(tenant).weight
        ledger.dataflows[df.name] = charged
        self.tenant_of[df.name] = tenant
        self.slots_used += charged
        self.naive_of[df.name] = len(df.tasks)
        self.naive_slots += len(df.tasks)
        return AdmissionResult(
            status=protocol.ADMITTED,
            name=df.name,
            tenant=tenant,
            slots_charged=charged,
            reused=receipt.num_reused,
            created=charged,
        )

    def _drain_pending(self) -> List[AdmissionResult]:
        """Admit queued submissions in weighted fair-share order.

        Repeatedly picks the lowest-vtime tenant whose *oldest* pending
        submission fits the free slots (arrival seq breaks vtime ties), so
        slots freed by a removal flow to the tenant furthest below its
        fair share. Lock held.
        """
        admitted: List[AdmissionResult] = []
        while self._pending:
            head_of: Dict[str, _Pending] = {}
            for p in self._pending:
                if p.tenant not in head_of:  # list is in arrival order
                    head_of[p.tenant] = p
            candidates = [
                p
                for p in head_of.values()
                if self.session.preview(p.df).num_created <= self.slots_free
            ]
            if not candidates:
                break
            pick = min(
                candidates,
                key=lambda p: (self.ledger_for(p.tenant).vtime, p.seq),
            )
            self._pending.remove(pick)
            admitted.append(self._admit(pick.tenant, pick.df))
        return admitted

    # -- removal ---------------------------------------------------------------
    def remove(self, tenant: str, name: str) -> Dict[str, Any]:
        """Remove a tenant's dataflow, free its slots, and admit whatever
        queued work now fits (fair-share order)."""
        with self._lock:
            owner = self.tenant_of.get(name)
            if owner is None:
                # Also allow cancelling a queued (not yet admitted) submission.
                for p in self._pending:
                    if p.df.name == name and p.tenant == tenant:
                        self._pending.remove(p)
                        return {"ok": True, "name": name, "cancelled": True,
                                "slots_freed": 0, "admitted": []}
                raise DataflowError(f"dataflow {name!r} is not admitted")
            if owner != tenant:
                raise DataflowError(
                    f"dataflow {name!r} belongs to tenant {owner!r}, not {tenant!r}"
                )
            self.session.remove(name)
            ledger = self.ledger_for(tenant)
            freed = ledger.dataflows.pop(name, 0)
            ledger.slots_held -= freed
            ledger.removed += 1
            del self.tenant_of[name]
            self.slots_used -= freed
            self.naive_slots -= self.naive_of.pop(name, 0)
            self._removes_since_defrag += 1
            if (
                self.defrag_every
                and self._removes_since_defrag >= self.defrag_every
            ):
                self.session.defragment()
                self._removes_since_defrag = 0
            admitted = self._drain_pending()
            return {
                "ok": True,
                "name": name,
                "cancelled": False,
                "slots_freed": freed,
                "admitted": [r.to_json() for r in admitted],
            }

    # -- execution & billing -----------------------------------------------------
    def step(self, steps: int = 1) -> Dict[str, Any]:
        """Advance the data plane ``steps`` steps, billing each step's
        core-equivalent cost to tenants: a running task's weight splits
        evenly among the submissions mapped onto it (reuse halves your
        bill), and each submission bills its tenant."""
        with self._lock:
            last = None
            for _ in range(steps):
                last = self.session.step()
                self._bill(last.cost)
                self.steps += 1
            return {
                "ok": True,
                "steps": steps,
                "step": last.step if last else self.steps,
                "live_tasks": last.live_tasks if last else 0,
                "cost": last.cost if last else 0.0,
            }

    def _bill(self, step_cost: float) -> None:
        """Split one step's cost across tenants by shared-task usage."""
        mgr = self.session.manager
        users: Dict[str, List[str]] = {}
        for sub_name, task_map in mgr.task_maps.items():
            for tid in set(task_map.values()):
                users.setdefault(tid, []).append(sub_name)
        weight_of: Dict[str, float] = {}
        total = 0.0
        backend = self.session._system.backend
        from repro.runtime.backend import PAUSE_EPSILON

        for seg in backend.segments.values():
            for tid in seg.spec.task_ids:
                w = seg.cost_of[tid] * seg.spec.batch_of[tid]
                if not bool(seg.active[tid]):
                    w *= PAUSE_EPSILON
                weight_of[tid] = weight_of.get(tid, 0.0) + w
                total += w
        if total <= 0.0:
            return
        scale = step_cost / total  # normalize model weights to billed cores
        for tid, subs in users.items():
            w = weight_of.get(tid)
            if not w:
                continue
            share = w * scale / len(subs)
            for sub_name in subs:
                tenant = self.tenant_of.get(sub_name)
                if tenant is not None:
                    self.ledger_for(tenant).cost_total += share

    # -- observability -----------------------------------------------------------
    def status(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "ok": True,
                "slots": self.slots,
                "slots_used": self.slots_used,
                "slots_free": self.slots_free,
                "pending": len(self._pending),
                "tenants": sorted(self.ledgers),
                "dataflows": len(self.tenant_of),
                "steps": self.steps,
                "draining": self.draining,
                "strategy": self.session.strategy,
                "backend": self.session.backend_name,
                # cluster plane: worker liveness/respawns/autoscale for the
                # multiproc backend, null for in-process data planes
                "worker_health": self.session.worker_health(),
            }

    def stats(self, tenant: Optional[str] = None) -> Dict[str, Any]:
        """Status plus per-tenant ledgers and the reuse dividend:
        ``effective_capacity`` is naive slots / slots actually used — how
        many pools' worth of work the one pool is carrying."""
        with self._lock:
            ledgers = (
                {tenant: self.ledger_for(tenant)}
                if tenant is not None
                else self.ledgers
            )
            out = self.status()
            out["naive_slots"] = self.naive_slots
            out["effective_capacity"] = (
                self.naive_slots / self.slots_used if self.slots_used else 1.0
            )
            out["ledgers"] = {t: l.to_json() for t, l in ledgers.items()}
            return out

    # -- telemetry plane ---------------------------------------------------------
    def _wire_serve_obs(self) -> None:
        """Register the serve-level collector on the session backend's
        metrics registry (idempotent per registry instance — re-run after
        ``configure_obs`` swaps the registry)."""
        system = getattr(self.session, "_system", None)
        if system is None:
            return
        registry = system.backend.metrics
        if registry is self._obs_registry:
            return
        registry.add_collector(self._collect_serve_obs)
        self._obs_registry = registry

    def _collect_serve_obs(self) -> None:
        """Mirror admission/ledger state into the registry at scrape time.

        Lock order matches the admission path (frontend lock, then
        registry lock), so a mid-churn scrape can never deadlock and
        always sees a consistent ledger snapshot.
        """
        m = self._obs_registry
        if m is None:
            return
        with self._lock:
            m.gauge("repro_serve_slots", "admission slot pool size").set(self.slots)
            m.gauge(
                "repro_serve_slots_used", "slots currently charged to tenants"
            ).set(self.slots_used)
            m.gauge(
                "repro_serve_pending", "submissions queued for fair-share admission"
            ).set(len(self._pending))
            m.gauge(
                "repro_serve_naive_slots",
                "slots a reuse-disabled pool would be holding for the same work",
            ).set(self.naive_slots)
            m.gauge(
                "repro_serve_effective_capacity",
                "naive slots over slots actually used — pools' worth of work "
                "the one pool is carrying thanks to reuse",
            ).set(self.naive_slots / self.slots_used if self.slots_used else 1.0)
            for tenant, ledger in self.ledgers.items():
                m.gauge(
                    "repro_serve_slots_held",
                    "slots currently held, by tenant",
                ).set(ledger.slots_held, tenant=tenant)
                m.gauge(
                    "repro_serve_slots_saved",
                    "cumulative slots not charged because the submission "
                    "reused running tasks, by tenant",
                ).set(ledger.slots_saved, tenant=tenant)
                m.gauge(
                    "repro_serve_cost_total",
                    "cumulative core-equivalent step cost billed, by tenant",
                ).set(ledger.cost_total, tenant=tenant)

    def metrics(self) -> Dict[str, Any]:
        """The merged telemetry snapshot, both machine forms: ``text`` is
        Prometheus exposition 0.0.4 (what the HTTP listener serves),
        ``snapshot`` the raw registry JSON."""
        from repro.obs import render_prometheus

        self._wire_serve_obs()
        if getattr(self.session, "_system", None) is None:
            return {"ok": True, "text": "", "snapshot": {}}
        snapshot = self.session.metrics_snapshot()
        return {"ok": True, "text": render_prometheus(snapshot), "snapshot": snapshot}

    def start_metrics_http(self, port: Optional[int] = None) -> Tuple[str, int]:
        """Serve ``GET /metrics`` as plain-HTTP Prometheus text on a daemon
        thread; returns ``(host, port)``. Started automatically by
        :meth:`start` when the frontend was built with ``metrics_port=``;
        callable directly for in-process use (``port=0`` → ephemeral)."""
        if self._metrics_sock is not None:
            return self._metrics_sock.getsockname()[:2]
        bind_port = self.metrics_port if port is None else port
        sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        sock.bind((self.host, int(bind_port or 0)))
        sock.listen(16)
        self._metrics_sock = sock
        self._metrics_thread = threading.Thread(
            target=self._metrics_http_loop, name="serve-metrics-http", daemon=True
        )
        self._metrics_thread.start()
        return sock.getsockname()[:2]

    def stop_metrics_http(self) -> None:
        sock = self._metrics_sock
        if sock is None:
            return
        self._metrics_sock = None
        try:
            sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            sock.close()
        except OSError:
            pass
        if self._metrics_thread is not None:
            self._metrics_thread.join(timeout=5.0)
            self._metrics_thread = None

    def _metrics_http_loop(self) -> None:
        sock = self._metrics_sock
        while self._metrics_sock is sock:
            try:
                conn, _addr = sock.accept()
            except OSError:
                break
            try:
                conn.settimeout(2.0)
                data = b""
                while b"\r\n\r\n" not in data and len(data) < 65536:
                    chunk = conn.recv(4096)
                    if not chunk:
                        break
                    data += chunk
                parts = data.split(b"\r\n", 1)[0].decode("latin-1", "replace").split()
                path = (parts[1] if len(parts) > 1 else "/").split("?")[0]
                if path in ("/metrics", "/"):
                    body = self.metrics()["text"].encode("utf-8")
                    head = (
                        "HTTP/1.1 200 OK\r\n"
                        "Content-Type: text/plain; version=0.0.4; charset=utf-8\r\n"
                        f"Content-Length: {len(body)}\r\nConnection: close\r\n\r\n"
                    ).encode("latin-1")
                else:
                    body = b"not found\n"
                    head = (
                        "HTTP/1.1 404 Not Found\r\nContent-Type: text/plain\r\n"
                        f"Content-Length: {len(body)}\r\nConnection: close\r\n\r\n"
                    ).encode("latin-1")
                conn.sendall(head + body)
            except OSError:
                pass
            finally:
                try:
                    conn.close()
                except OSError:
                    pass

    # -- durability ----------------------------------------------------------------
    def _ledger_payload(self) -> Dict[str, Any]:
        return {
            "version": 2,
            "slots": self.slots,
            "slots_used": self.slots_used,
            "naive_slots": self.naive_slots,
            "steps": self.steps,
            "tenant_of": dict(self.tenant_of),
            "naive_of": dict(self.naive_of),
            "ledgers": {t: l.to_json() for t, l in self.ledgers.items()},
            "quotas": {t: q.to_json() for t, q in self.quotas.items()},
            "default_quota": self.default_quota.to_json(),
            # the QUEUED admission queue, in arrival order — encoded
            # dataflows so a restart re-enqueues instead of dropping them
            "pending": [
                {"tenant": p.tenant, "seq": p.seq,
                 "dataflow": protocol.encode_dataflow(p.df)}
                for p in self._pending
            ],
            "pending_seq": self._seq,
        }

    def _load_ledger_payload(self, payload: Dict[str, Any]) -> None:
        self.slots = int(payload["slots"])
        self.slots_used = int(payload["slots_used"])
        self.naive_slots = int(payload["naive_slots"])
        self.steps = int(payload["steps"])
        self.tenant_of = dict(payload["tenant_of"])
        self.naive_of = {k: int(v) for k, v in payload["naive_of"].items()}
        self.ledgers = {
            t: TenantLedger.from_json(l) for t, l in payload["ledgers"].items()
        }
        self.quotas = {
            t: TenantQuota.from_json(q) for t, q in payload["quotas"].items()
        }
        self.default_quota = TenantQuota.from_json(payload["default_quota"])
        # version-1 sidecars have no pending queue — tolerate their absence
        self._pending = [
            _Pending(tenant=p["tenant"],
                     df=protocol.decode_dataflow(p["dataflow"]),
                     seq=int(p["seq"]))
            for p in payload.get("pending", [])
        ]
        self._seq = int(payload.get("pending_seq", self._seq))
        if self._pending:
            self._seq = max(self._seq, max(p.seq for p in self._pending) + 1)

    def checkpoint(self, checkpoint_dir: Optional[str] = None) -> str:
        """One durable checkpoint: session state via the checkpoint store,
        tenant ledgers as an atomic JSON sidecar in the same directory."""
        with self._lock:
            path = self.session.checkpoint(checkpoint_dir)
            root = checkpoint_dir or os.path.dirname(path)
            sidecar = os.path.join(root, _LEDGER_FILE)
            tmp = sidecar + ".tmp"
            with open(tmp, "w", encoding="utf-8") as fh:
                json.dump(self._ledger_payload(), fh)
                fh.flush()
                os.fsync(fh.fileno())
            os.replace(tmp, sidecar)
            return path

    @classmethod
    def restore(cls, checkpoint_dir: str, **kwargs: Any) -> "ServeFrontend":
        """Rebuild frontend + session from ``checkpoint_dir``: the session
        restores from the newest valid checkpoint
        (:meth:`ReuseSession.restore`), the tenant ledgers — including the
        QUEUED admission queue — from the sidecar. Re-enqueued submissions
        go through one fair-share drain pass immediately, so whatever now
        fits is admitted before the first post-restore request arrives."""
        from repro.api import ReuseSession

        session_kwargs = {
            k: kwargs.pop(k)
            for k in ("backend", "step_mode", "max_workers", "supervise",
                      "autoscale", "on_worker_event", "transport", "workers")
            if k in kwargs
        }
        session = ReuseSession.restore(checkpoint_dir, **session_kwargs)
        frontend = cls(session=session, checkpoint_dir=checkpoint_dir, **kwargs)
        sidecar = os.path.join(checkpoint_dir, _LEDGER_FILE)
        if os.path.exists(sidecar):
            with open(sidecar, "r", encoding="utf-8") as fh:
                frontend._load_ledger_payload(json.load(fh))
            with frontend._lock:
                frontend._drain_pending()
        return frontend

    # -- lifecycle ---------------------------------------------------------------
    def drain(self) -> Dict[str, Any]:
        """Stop accepting, run one final fair-share pass, reject the
        remainder, and quiesce the data plane."""
        with self._lock:
            self.draining = True
            admitted = self._drain_pending()
            shed = []
            for p in self._pending:
                self.ledger_for(p.tenant).rejected += 1
                shed.append({"tenant": p.tenant, "name": p.df.name})
            self._pending.clear()
            self.session.quiesce()
            return {
                "ok": True,
                "admitted": [r.to_json() for r in admitted],
                "shed": shed,
            }

    def close(self) -> None:
        """Stop the socket server (if running) and release the session."""
        self.stop()
        self.session.close()

    def __enter__(self) -> "ServeFrontend":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()

    # -- socket server ------------------------------------------------------------
    @property
    def address(self) -> Tuple[str, int]:
        if self._sock is None:
            raise RuntimeError("server not started")
        return self._sock.getsockname()[:2]

    def start(self) -> Tuple[str, int]:
        """Bind, listen and serve on a daemon thread; returns (host, port).
        SO_REUSEADDR + per-connection timeouts mean a restart rebinds the
        same port immediately even with stale client sockets around."""
        if self._sock is not None:
            raise RuntimeError("server already started")
        sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        sock.bind((self.host, self.port))
        sock.listen(64)
        self._sock = sock
        self._closed = False
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="serve-frontend-accept", daemon=True
        )
        self._accept_thread.start()
        host, port = self.address
        logger.info("serving on %s:%d", host, port)
        if self.metrics_port is not None and self._metrics_sock is None:
            mhost, mport = self.start_metrics_http()
            logger.info("metrics on http://%s:%d/metrics", mhost, mport)
        return host, port

    def serve_forever(self) -> None:
        """Block until a shutdown request (or :meth:`stop`) arrives."""
        if self._sock is None:
            self.start()
        self._shutdown_event.wait()

    def stop(self) -> None:
        """Close the listener and all live connections; joins the accept
        thread. Idempotent."""
        self.stop_metrics_http()
        if self._sock is None:
            return
        self._closed = True
        self._shutdown_event.set()
        # shutdown() before close(): close() alone doesn't wake a thread
        # blocked in accept(), which would keep the port bound.
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self._sock.close()
        except OSError:
            pass
        with self._conns_lock:
            conns = list(self._conns)
            self._conns.clear()
        for conn in conns:
            try:
                conn.close()
            except OSError:
                pass
        if self._accept_thread is not None:
            self._accept_thread.join(timeout=5.0)
            self._accept_thread = None
        for t in self._conn_threads:
            t.join(timeout=5.0)
        self._conn_threads = []
        self._sock = None

    def _accept_loop(self) -> None:
        assert self._sock is not None
        while not self._closed:
            try:
                conn, _addr = self._sock.accept()
            except OSError:
                break
            with self._conns_lock:
                if self._closed:
                    conn.close()
                    break
                self._conns.add(conn)
            t = threading.Thread(
                target=self._serve_conn, args=(conn,), daemon=True
            )
            t.start()
            self._conn_threads.append(t)

    def _serve_conn(self, conn: socket.socket) -> None:
        conn.settimeout(self.conn_timeout)
        try:
            while not self._closed:
                try:
                    request = protocol.recv_request_idle(conn)
                except (ConnectionError, OSError):
                    break
                if request is None:  # idle poll — re-check _closed
                    continue
                try:
                    response = self._handle(request)
                except DataflowError as e:
                    response = {"error": str(e)}
                except Exception as e:  # noqa: BLE001 — wire must answer
                    logger.exception("request failed: %r", request.get("op"))
                    response = {"error": f"{type(e).__name__}: {e}"}
                try:
                    protocol.send_response(conn, response)
                except (ConnectionError, OSError):
                    break
                if request.get("op") == protocol.SHUTDOWN:
                    ack = self._stop_ack
                    if ack is not None:
                        ack.set()
        finally:
            with self._conns_lock:
                self._conns.discard(conn)
            try:
                conn.close()
            except OSError:
                pass

    def _handle(self, request: Dict[str, Any]) -> Dict[str, Any]:
        op = request.get("op")
        if op == protocol.PING:
            return {"ok": True}
        if op == protocol.SUBMIT:
            df = protocol.decode_dataflow(request["dataflow"])
            return self.submit(request["tenant"], df).to_json()
        if op == protocol.REMOVE:
            return self.remove(request["tenant"], request["name"])
        if op == protocol.STATUS:
            return self.status()
        if op == protocol.STATS:
            return self.stats(request.get("tenant"))
        if op == protocol.STEP:
            return self.step(int(request.get("steps", 1)))
        if op == protocol.METRICS:
            return self.metrics()
        if op == protocol.CHECKPOINT:
            return {"ok": True, "path": self.checkpoint()}
        if op == protocol.DRAIN:
            return self.drain()
        if op == protocol.SHUTDOWN:
            out: Dict[str, Any] = {"ok": True}
            with self._lock:
                self.draining = True
                if request.get("checkpoint", True) and (
                    self.session._system is not None
                    and self.session._system.checkpoint_store is not None
                ):
                    out["path"] = self.checkpoint()
            # Stop from a helper thread, but only after the conn loop has
            # flushed this response (it sets _stop_ack) — otherwise stop()
            # can close the socket under the reply and the client sees
            # ConnectionError instead of {"ok": true}.
            ack = threading.Event()
            self._stop_ack = ack

            def _stop_after_reply() -> None:
                ack.wait(timeout=2.0)
                self.stop()

            threading.Thread(target=_stop_after_reply, daemon=True).start()
            self._shutdown_event.set()
            return out
        raise DataflowError(f"unknown op {op!r} (expected one of {sorted(protocol.VERBS)})")
