"""Multi-tenant LM serving with collaborative dataflow reuse — the
paper's technique as a first-class framework feature.

Tenant pipelines over shared request streams duplicate backbone prefix
work (same base checkpoint, same lower layer ranges). Expressed as
dataflows and routed through :class:`repro.core.ReuseManager`, N tenants
sharing a backbone pay for **one** copy of the shared prefix; each keeps
its own adapter/head and any fine-tuned upper stages. Removing a tenant
unmerges per the paper §4.2 — surviving tenants are untouched.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.graph import Dataflow, Task, SINK_CONFIG, SOURCE_CONFIG
from repro.runtime.system import StreamSystem

from . import model_ops  # noqa: F401 — registers lm_* operator types


@dataclass(frozen=True)
class TenantPipeline:
    """Declarative tenant spec.

    ``shared_stages`` of the backbone come from the base checkpoint
    (reusable across tenants of the same model); stages above that are
    tenant-fine-tuned (configs embed the tenant's checkpoint id, so they
    are never falsely merged). ``d``/``layers_per_stage`` control cost.
    """

    tenant: str
    stream: str = "urban"          # request source stream
    model: str = "base-7b@v1"      # base checkpoint id
    d: int = 64
    n_stages: int = 4
    layers_per_stage: int = 4
    shared_stages: Optional[int] = None  # default: all stages shared
    adapter: str = ""              # tenant head/adapter checkpoint id

    def to_dataflow(self) -> Dataflow:
        df = Dataflow(self.tenant)
        src = Task.make(f"{self.tenant}/src", f"prompts:{self.stream}", SOURCE_CONFIG)
        df.add_task(src)
        prev = src.id
        emb = Task.make(
            f"{self.tenant}/embed", "lm_embed", {"model": self.model, "d": self.d}
        )
        df.add_task(emb)
        df.add_stream(prev, emb.id)
        prev = emb.id
        shared = self.n_stages if self.shared_stages is None else self.shared_stages
        for s in range(self.n_stages):
            lo = s * self.layers_per_stage
            hi = lo + self.layers_per_stage - 1
            ckpt = self.model if s < shared else f"{self.model}+ft:{self.tenant}"
            t = Task.make(
                f"{self.tenant}/stage{s}",
                "lm_stage",
                {"model": ckpt, "layers": f"{lo}-{hi}", "d": self.d},
            )
            df.add_task(t)
            df.add_stream(prev, t.id)
            prev = t.id
        head = Task.make(
            f"{self.tenant}/head",
            "lm_head",
            {"model": self.model, "adapter": self.adapter or self.tenant, "d": self.d},
        )
        df.add_task(head)
        df.add_stream(prev, head.id)
        sink = Task.make(f"{self.tenant}/sink", f"respond:{self.tenant}", SINK_CONFIG)
        df.add_task(sink)
        df.add_stream(head.id, sink.id)
        return df


def backbone_pipeline(tenant: str, **kw) -> TenantPipeline:
    return TenantPipeline(tenant=tenant, **kw)


class ReuseServing:
    """StreamSystem wrapper speaking tenants instead of raw dataflows.

    ``backend`` picks the data plane from the ExecutionBackend registry:
    ``"inprocess"`` (default) serves real batches through the jit plane;
    ``"dryrun"`` gives capacity-planning answers (tenant counts, deployed
    cost) without touching JAX; ``"sharded"`` spreads tenant segments over
    ``jax.devices()``.
    """

    def __init__(
        self, strategy: str = "signature", base_batch: int = 8, backend: str = "inprocess"
    ):
        self.system = StreamSystem(strategy=strategy, base_batch=base_batch, backend=backend)
        self.tenants: Dict[str, TenantPipeline] = {}

    def add_tenant(self, pipe: TenantPipeline):
        receipt = self.system.submit(pipe.to_dataflow())
        self.tenants[pipe.tenant] = pipe
        return receipt

    def remove_tenant(self, tenant: str):
        del self.tenants[tenant]
        return self.system.remove(tenant)

    def step(self):
        return self.system.step()

    def run(self, steps: int):
        return self.system.run(steps)

    def tenant_output(self, tenant: str):
        return self.system.sink_digests(tenant)

    @property
    def running_task_count(self) -> int:
        return self.system.running_task_count

    def stats(self) -> Dict[str, float]:
        deployed_cost = 0.0
        for seg in self.system.backend.segments.values():
            for tid in seg.live_task_ids():
                deployed_cost += seg.cost_of[tid]
        return {
            "tenants": len(self.tenants),
            "running_tasks": self.system.running_task_count,
            "deployed_tasks": self.system.deployed_task_count,
            "deployed_cost": deployed_cost,
        }
