"""ServeClient — blocking client for the serving front end.

One persistent socket per client, one request/response exchange per call
(the protocol is strictly serial per connection). Read-only verbs
(``ping``/``status``/``stats``) reconnect-and-retry once on a broken
connection; mutating verbs never retry — a lost response to ``submit``
could otherwise double-submit.

    with ServeClient(("127.0.0.1", 7421)) as client:
        result = client.submit("alice", df, wait=True)   # loops on RETRY_AFTER
        print(client.stats("alice")["ledgers"]["alice"]["slots_held"])
"""
from __future__ import annotations

import random
import socket
import time
from typing import Any, Dict, Optional, Tuple, Union

from repro.core.graph import Dataflow

from . import protocol


class SubmitTimeout(TimeoutError):
    """``submit(wait=True)`` exhausted ``max_wait`` while the frontend kept
    answering RETRY_AFTER. Carries the last server response so callers can
    inspect the final backpressure hint instead of a silent non-admission."""

    def __init__(self, tenant: str, max_wait: float, last: Dict[str, Any]):
        self.tenant = tenant
        self.max_wait = max_wait
        self.last = last
        super().__init__(
            f"submit for tenant {tenant!r} still backpressured after "
            f"{max_wait:.1f}s (last status: {last.get('status')})"
        )


class ServeClient:
    def __init__(
        self,
        address: Tuple[str, int],
        timeout: float = 30.0,
    ):
        self.address = (address[0], int(address[1]))
        self.timeout = timeout
        self._sock: Optional[socket.socket] = None

    # -- plumbing -----------------------------------------------------------------
    def _connect(self) -> socket.socket:
        if self._sock is None:
            sock = socket.create_connection(self.address, timeout=self.timeout)
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            self._sock = sock
        return self._sock

    def _drop(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None

    def _call(self, op: str, *, retry: bool = False, **fields: Any) -> Dict[str, Any]:
        attempts = 2 if retry else 1
        for attempt in range(attempts):
            sock = self._connect()
            try:
                protocol.send_request(sock, op, **fields)
                return protocol.recv_response(sock)
            except (ConnectionError, OSError, socket.timeout):
                self._drop()
                if attempt + 1 >= attempts:
                    raise
        raise AssertionError("unreachable")

    def close(self) -> None:
        self._drop()

    def __enter__(self) -> "ServeClient":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()

    # -- verbs --------------------------------------------------------------------
    def ping(self) -> bool:
        return bool(self._call(protocol.PING, retry=True).get("ok"))

    def submit(
        self,
        tenant: str,
        df: Union[Dataflow, Any],
        *,
        wait: bool = False,
        max_wait: float = 60.0,
    ) -> Dict[str, Any]:
        """Submit one dataflow for ``tenant``. With ``wait=True`` the client
        sleeps out RETRY_AFTER backpressure with jittered exponential
        backoff (base delay from the server's ``retry_after`` hint, capped
        at 5s) and resubmits; QUEUED and REJECTED return immediately either
        way. Raises :class:`SubmitTimeout` once ``max_wait`` elapses with
        the server still answering RETRY_AFTER — waiting callers never see
        a RETRY_AFTER result, and never hang past the deadline."""
        from repro.api.builder import as_dataflow

        payload = protocol.encode_dataflow(as_dataflow(df))
        deadline = time.monotonic() + max_wait
        attempt = 0
        while True:
            result = self._call(protocol.SUBMIT, tenant=tenant, dataflow=payload)
            if not (wait and result.get("status") == protocol.RETRY_AFTER):
                return result
            now = time.monotonic()
            if now >= deadline:
                raise SubmitTimeout(tenant, max_wait, result)
            base = float(result.get("retry_after", 0.5))
            # full backoff doubles per attempt; jitter in [0.5, 1.0) spreads
            # synchronized waiters so they don't stampede the frontend
            delay = min(base * (2.0 ** attempt), 5.0)
            delay *= 0.5 + random.random() * 0.5
            time.sleep(min(delay, max(deadline - now, 0.0)))
            attempt += 1

    def remove(self, tenant: str, name: str) -> Dict[str, Any]:
        return self._call(protocol.REMOVE, tenant=tenant, name=name)

    def status(self) -> Dict[str, Any]:
        return self._call(protocol.STATUS, retry=True)

    def stats(self, tenant: Optional[str] = None) -> Dict[str, Any]:
        fields = {"tenant": tenant} if tenant is not None else {}
        return self._call(protocol.STATS, retry=True, **fields)

    def step(self, steps: int = 1) -> Dict[str, Any]:
        return self._call(protocol.STEP, steps=steps)

    def metrics(self) -> Dict[str, Any]:
        """Telemetry scrape: ``{"text": <Prometheus 0.0.4>, "snapshot":
        <raw registry JSON>}``. Read-only, so it reconnect-retries."""
        return self._call(protocol.METRICS, retry=True)

    def checkpoint(self) -> str:
        return self._call(protocol.CHECKPOINT)["path"]

    def drain(self) -> Dict[str, Any]:
        return self._call(protocol.DRAIN)

    def shutdown(self, *, checkpoint: bool = True) -> Dict[str, Any]:
        out = self._call(protocol.SHUTDOWN, checkpoint=checkpoint)
        self._drop()
        return out

    # -- helpers ------------------------------------------------------------------
    @staticmethod
    def wait_ready(
        address: Tuple[str, int], timeout: float = 10.0, interval: float = 0.05
    ) -> "ServeClient":
        """Poll until a frontend answers ping at ``address``; returns a
        connected client. For scripts racing a freshly-started server."""
        deadline = time.monotonic() + timeout
        last: Optional[Exception] = None
        while time.monotonic() < deadline:
            client = ServeClient(address, timeout=max(interval * 4, 1.0))
            try:
                if client.ping():
                    client.timeout = 30.0
                    if client._sock is not None:
                        client._sock.settimeout(client.timeout)
                    return client
            except (ConnectionError, OSError, socket.timeout) as e:
                last = e
                client.close()
            time.sleep(interval)
        raise ConnectionError(
            f"no serving frontend answered at {address[0]}:{address[1]} "
            f"within {timeout:.1f}s"
        ) from last
