"""Serving: batched prefill/decode engine and the multi-tenant
reuse-serving integration of the paper's merge algorithms."""
from .engine import ServeEngine, GenerationResult
from .reuse_serving import TenantPipeline, ReuseServing, backbone_pipeline

__all__ = [
    "GenerationResult",
    "ReuseServing",
    "ServeEngine",
    "TenantPipeline",
    "backbone_pipeline",
]
