"""Serving: the multi-tenant dataflow front end (slot-based admission over
collaborative reuse), its wire protocol and client, plus the batched
prefill/decode engine and the library-level reuse-serving integration.

Imports resolve lazily (PEP 562): the front end / protocol / client stack
is JAX-free (``ServeFrontend(backend="dryrun")`` never imports JAX), while
``ServeEngine`` and the model-serving pipeline load JAX on first access.
"""
from __future__ import annotations

import importlib
from typing import TYPE_CHECKING

from . import protocol
from .client import ServeClient, SubmitTimeout
from .frontend import (
    AdmissionResult,
    ServeFrontend,
    TenantLedger,
    TenantQuota,
)

# name -> (module, attribute); resolved on first access to keep JAX lazy.
_LAZY = {
    "GenerationResult": ("repro.serve.engine", "GenerationResult"),
    "ServeEngine": ("repro.serve.engine", "ServeEngine"),
    "ReuseServing": ("repro.serve.reuse_serving", "ReuseServing"),
    "TenantPipeline": ("repro.serve.reuse_serving", "TenantPipeline"),
    "backbone_pipeline": ("repro.serve.reuse_serving", "backbone_pipeline"),
}

if TYPE_CHECKING:  # pragma: no cover - static imports for type checkers
    from .engine import GenerationResult, ServeEngine
    from .reuse_serving import ReuseServing, TenantPipeline, backbone_pipeline

__all__ = [
    "AdmissionResult",
    "GenerationResult",
    "ReuseServing",
    "ServeClient",
    "ServeEngine",
    "ServeFrontend",
    "SubmitTimeout",
    "TenantLedger",
    "TenantPipeline",
    "TenantQuota",
    "backbone_pipeline",
    "protocol",
]


def __getattr__(name: str):
    try:
        module, attr = _LAZY[name]
    except KeyError:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}") from None
    value = getattr(importlib.import_module(module), attr)
    globals()[name] = value  # cache for subsequent lookups
    return value
