"""Wire protocol for the multi-tenant serving front end.

Framing rides the ``tcp`` transport's length-prefixed socket machinery
verbatim (``u32 header length | JSON header | u32 payload length | raw
payload``) — requests and responses are header-only JSON messages, the
payload side of the frame stays empty. Dataflows travel inside the header
as their canonical :meth:`~repro.core.graph.Dataflow.to_json` form.

Request verbs (``{"op": <verb>, ...}``):

  ========== ==========================================================
  verb       fields
  ========== ==========================================================
  submit     ``tenant``, ``dataflow`` (Dataflow JSON)
  remove     ``tenant``, ``name``
  status     —
  stats      optional ``tenant``
  step       optional ``steps`` (default 1)
  metrics    —
  checkpoint —
  drain      —
  shutdown   optional ``checkpoint`` (default true)
  ping       —
  ========== ==========================================================

Responses always carry ``"ok": true`` or ``"error": "<message>"``; submit
responses additionally carry an admission ``"status"``:

  * ``ADMITTED``    — running; ``slots_charged``/``reused``/``created``
    report the slot accounting (reused segments cost 0 slots).
  * ``QUEUED``      — accepted into the tenant's pending queue; admitted
    later in weighted fair-share order as slots free up.
  * ``RETRY_AFTER`` — backpressure: the slot pool is saturated AND the
    tenant's pending queue is full; ``retry_after`` is the resubmit hint
    in seconds.
  * ``REJECTED``    — can never be admitted under the current quota (cost
    exceeds the tenant's ``max_slots`` or the whole pool), or the server
    is draining, or the name is a duplicate.

This module is JAX-free and deliberately tiny: constants, the dataflow
codec, and the send/recv helpers shared by :class:`ServeFrontend` and
:class:`ServeClient`.
"""
from __future__ import annotations

import socket
from typing import Any, Dict, Optional

from repro.core.graph import Dataflow
from repro.runtime.transport import _recv_msg, _recv_msg_idle, _send_msg

# -- verbs ----------------------------------------------------------------------
SUBMIT = "submit"
REMOVE = "remove"
STATUS = "status"
STATS = "stats"
STEP = "step"
METRICS = "metrics"
CHECKPOINT = "checkpoint"
DRAIN = "drain"
SHUTDOWN = "shutdown"
PING = "ping"

VERBS = frozenset(
    {SUBMIT, REMOVE, STATUS, STATS, STEP, METRICS, CHECKPOINT, DRAIN, SHUTDOWN, PING}
)

# -- admission statuses ---------------------------------------------------------
ADMITTED = "ADMITTED"
QUEUED = "QUEUED"
RETRY_AFTER = "RETRY_AFTER"
REJECTED = "REJECTED"


class ServeProtocolError(RuntimeError):
    """The server reported an error for a request (bad verb, bad tenant…)."""


def encode_dataflow(df: Dataflow) -> Dict[str, Any]:
    return df.to_json()


def decode_dataflow(obj: Dict[str, Any]) -> Dataflow:
    return Dataflow.from_json(obj)


# -- socket helpers -------------------------------------------------------------


def send_request(sock: socket.socket, op: str, **fields: Any) -> None:
    _send_msg(sock, dict(fields, op=op))


def recv_request_idle(sock: socket.socket) -> Optional[Dict[str, Any]]:
    """Server side: one request header, or ``None`` on an idle poll timeout
    (see :func:`repro.runtime.transport._recv_msg_idle`)."""
    msg = _recv_msg_idle(sock)
    return None if msg is None else msg[0]


def send_response(sock: socket.socket, response: Dict[str, Any]) -> None:
    _send_msg(sock, response)


def recv_response(sock: socket.socket) -> Dict[str, Any]:
    """Client side: one response header; raises on a server-side error."""
    header, _payload = _recv_msg(sock)
    if "error" in header:
        raise ServeProtocolError(header["error"])
    return header
