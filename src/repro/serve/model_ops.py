"""LM-pipeline task operators for multi-tenant reuse-serving.

A tenant's serving pipeline is a dataflow of typed stages:

  prompts:<stream> → lm_embed → lm_stage("0-7") → … → lm_head(<adapter>) → SINK

Stage weights are a *pure function of the config* (seeded by
``(model, layer range, d)``), so two tenants configured with the same
checkpoint id and layer range have **identical** operators — exactly the
paper's ⟨type, config⟩ equality — and the merge algorithm's reuse of a
stage is provably output-preserving. A tenant with a different adapter or
a fine-tuned upper range shares only the common prefix, which is the
interesting (and realistic) multi-tenant case.

Event contract: upstream sources emit (B, EVENT_WIDTH) request feature
batches; ``lm_embed`` lifts them to (B, d); stages are (B, d) → (B, d);
``lm_head`` folds back to (B, EVENT_WIDTH) response digests so the stock
digest sinks apply.
"""
from __future__ import annotations

import hashlib
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.ops.base import EVENT_WIDTH, Operator, register
from repro.ops.costs import LM_EMBED_COST, LM_HEAD_COST, LM_STAGE_COST_PER_BLOCK


def _seed(*parts: Any) -> int:
    h = hashlib.sha256("|".join(str(p) for p in parts).encode()).digest()
    return int.from_bytes(h[:4], "little")


def _proj(seed: int, shape) -> jnp.ndarray:
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32) * (
        shape[0] ** -0.5
    )


def _rms(x: jnp.ndarray) -> jnp.ndarray:
    return x * jax.lax.rsqrt(jnp.mean(jnp.square(x), -1, keepdims=True) + 1e-6)


@register("lm_embed")
def lm_embed(cfg: Dict[str, Any]) -> Operator:
    d = int(cfg.get("d", 64))
    w = _proj(_seed("embed", cfg.get("model", ""), d), (EVENT_WIDTH, d))

    def init_state(batch: int):
        return ()

    def apply(state, x):
        return state, _rms(jnp.tanh(x @ w))

    return Operator("lm_embed", init_state, apply, cost_weight=LM_EMBED_COST)


@register("lm_stage")
def lm_stage(cfg: Dict[str, Any]) -> Operator:
    """A contiguous group of transformer-ish blocks of the backbone."""
    d = int(cfg.get("d", 64))
    model = cfg.get("model", "")
    lo, hi = (int(v) for v in str(cfg.get("layers", "0-0")).split("-"))
    blocks = []
    for i in range(lo, hi + 1):
        s = _seed("stage", model, i, d)
        blocks.append((_proj(s, (d, 2 * d)), _proj(s + 1, (2 * d, d))))

    def init_state(batch: int):
        return ()

    def apply(state, x):
        h = x
        for w1, w2 in blocks:
            h = h + jax.nn.silu(_rms(h) @ w1) @ w2
        return state, h

    return Operator(
        "lm_stage", init_state, apply, cost_weight=LM_STAGE_COST_PER_BLOCK * len(blocks)
    )


@register("lm_head")
def lm_head(cfg: Dict[str, Any]) -> Operator:
    """Tenant adapter + response digest (B, d) → (B, EVENT_WIDTH)."""
    d = int(cfg.get("d", 64))
    s = _seed("head", cfg.get("model", ""), cfg.get("adapter", ""), d)
    wa = _proj(s, (d, d))
    wo = _proj(s + 1, (d, EVENT_WIDTH))

    def init_state(batch: int):
        return ()

    def apply(state, x):
        h = x + jax.nn.silu(_rms(x) @ wa)
        return state, _rms(h) @ wo

    return Operator("lm_head", init_state, apply, cost_weight=LM_HEAD_COST)
